//! Worker process: owns one chunk of the data, answers the leader's
//! protocol. Internally it is just a [`NativeBackend`] over the chunk —
//! the same restricted-Gibbs kernel runs on every tier of the system.

use super::wire::{read_message, write_message, Message};
use crate::backend::native::{NativeBackend, NativeConfig};
use crate::backend::Backend;
use crate::datagen::Data;
use crate::rng::Xoshiro256pp;
use anyhow::{Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// Worker session state (built on Init).
struct WorkerState {
    backend: NativeBackend,
}

fn handle(stream: &mut TcpStream, state: &mut Option<WorkerState>) -> Result<bool> {
    let msg = read_message(stream)?;
    let reply = match msg {
        Message::Init { d, prior, seed, threads, x } => {
            let d = d as usize;
            let n = x.len() / d.max(1);
            let data = Arc::new(Data::new(n, d, x));
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            // Workers run the same tiled assignment kernel as the local
            // CPU-threaded backend (shard.rs is the single hot path for
            // every tier); the default picks up DPMM_ASSIGN_KERNEL so the
            // scalar oracle can be selected per worker process.
            let config = NativeConfig {
                threads: (threads as usize).max(1),
                ..NativeConfig::default()
            };
            let backend = NativeBackend::new(data, prior, config, &mut rng);
            *state = Some(WorkerState { backend });
            Message::Ack
        }
        Message::Step(params) => match state.as_mut() {
            Some(ws) => match ws.backend.step(&params) {
                Ok(bundle) => Message::StatsReply(bundle.sub_stats),
                Err(e) => Message::Error(format!("step failed: {e}")),
            },
            None => Message::Error("Step before Init".into()),
        },
        Message::ApplySplits(ops) => match state.as_mut() {
            Some(ws) => {
                ws.backend.apply_splits(&ops)?;
                Message::Ack
            }
            None => Message::Error("ApplySplits before Init".into()),
        },
        Message::ApplyMerges(ops) => match state.as_mut() {
            Some(ws) => {
                ws.backend.apply_merges(&ops)?;
                Message::Ack
            }
            None => Message::Error("ApplyMerges before Init".into()),
        },
        Message::Remap(map) => match state.as_mut() {
            Some(ws) => {
                let map: Vec<Option<usize>> =
                    map.into_iter().map(|m| m.map(|v| v as usize)).collect();
                ws.backend.remap(&map)?;
                Message::Ack
            }
            None => Message::Error("Remap before Init".into()),
        },
        Message::RandomizeLabels { k } => match state.as_mut() {
            Some(ws) => {
                ws.backend.randomize_labels(k as usize);
                Message::Ack
            }
            None => Message::Error("RandomizeLabels before Init".into()),
        },
        Message::GetLabels => match state.as_ref() {
            Some(ws) => {
                Message::Labels(ws.backend.labels()?.into_iter().map(|l| l as u32).collect())
            }
            None => Message::Error("GetLabels before Init".into()),
        },
        Message::Shutdown => {
            write_message(stream, &Message::Ack)?;
            return Ok(false);
        }
        other => Message::Error(format!("unexpected message {other:?}")),
    };
    write_message(stream, &reply)?;
    Ok(true)
}

/// Serve a single leader connection to completion (Shutdown or EOF).
pub fn serve_connection(mut stream: TcpStream) -> Result<()> {
    // NODELAY + I/O timeouts: a leader that dies mid-protocol unblocks the
    // worker within one timeout instead of wedging it forever.
    super::wire::configure_stream(&stream).ok();
    let mut state: Option<WorkerState> = None;
    loop {
        match handle(&mut stream, &mut state) {
            Ok(true) => continue,
            Ok(false) => return Ok(()),
            Err(e) => {
                // EOF = leader went away; anything else is a real error.
                if e.downcast_ref::<std::io::Error>()
                    .map(|io| io.kind() == std::io::ErrorKind::UnexpectedEof)
                    .unwrap_or(false)
                {
                    return Ok(());
                }
                return Err(e);
            }
        }
    }
}

/// Bind and serve leaders forever (the `dpmm worker` CLI entrypoint).
/// One leader at a time — the paper's topology has exactly one master.
pub fn serve(addr: &str) -> Result<()> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("worker bind {addr}"))?;
    eprintln!("dpmm worker listening on {}", listener.local_addr()?);
    for stream in listener.incoming() {
        // A leader that times out or dies mid-protocol ends its connection
        // (I/O timeout via wire::configure_stream) but must not take the
        // worker process down — stay up for the next leader.
        if let Err(e) = serve_connection(stream?) {
            eprintln!("worker: leader connection ended with error: {e:#}");
        }
    }
    Ok(())
}

/// Spawn an in-process worker on an ephemeral port; returns its address.
/// Used by tests, examples, and `--workers N` convenience mode (the paper's
/// multi-machine topology collapsed onto localhost).
pub fn spawn_local() -> Result<String> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    std::thread::spawn(move || {
        if let Ok((stream, _)) = listener.accept() {
            if let Err(e) = serve_connection(stream) {
                eprintln!("worker error: {e}");
            }
        }
    });
    Ok(addr)
}
