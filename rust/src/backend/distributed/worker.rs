//! Worker process: owns one chunk of the data, answers the leader's
//! protocol. A connection runs one of two session kinds, decided by the
//! leader's opening message:
//!
//! * **batch** (`Init`): the PR-0 fit mode — the worker wraps its chunk in
//!   a [`NativeBackend`] and answers `Step`/`ApplySplits`/… (the same
//!   restricted-Gibbs kernel runs on every tier of the system).
//! * **streaming** (`StreamInit` / `StreamJoin`): the worker holds a
//!   *window slice* of a distributed stream — a [`StreamBuffer`] of routed
//!   mini-batches plus one persistent sweep-RNG per batch — and answers
//!   `StreamIngest`/`StreamSweep`/`StreamEvict` with grouped per-batch
//!   sufficient-statistics deltas ([`BatchDelta`]), plus the elastic v3
//!   verbs: `StreamBatchState` (checkpoint capture),
//!   `StreamRebalance`/`StreamRestore` (batches move between workers with
//!   labels and RNG streams intact). Points arrive once per residency;
//!   only O(K·d²) statistics flow back per sweep (see
//!   [`crate::stream::distributed`] for the leader half and
//!   docs/DETERMINISM.md for the contract).

use super::wire::{read_message_into, write_message_into, BatchDelta, BatchState, Message};
use crate::backend::native::{NativeBackend, NativeConfig};
use crate::backend::shard::{AssignKernel, Shard, DEFAULT_TILE};
use crate::backend::Backend;
use crate::datagen::Data;
use crate::rng::Xoshiro256pp;
use crate::sampler::StepParams;
use crate::stats::Prior;
use crate::stream::fitter::{fold_groups, map_seed, run_shards};
use crate::stream::StreamBuffer;
use anyhow::{Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Process-wide count of protocol verbs served, reported in
/// [`Message::Pong::generation`]. A worker that still answers pings but
/// whose generation stops advancing while the leader keeps issuing work is
/// wedged, not idle — the supervisor can tell the two apart.
static GENERATION: AtomicU64 = AtomicU64::new(0);
/// Window occupancy (points / resident batches) last published by a
/// streaming verb. Heartbeat probes arrive on their own short-lived
/// connections with no session state of their own, so the streaming
/// session mirrors its load here after every verb it handles.
static STREAM_POINTS: AtomicU64 = AtomicU64::new(0);
static STREAM_DEPTH: AtomicU64 = AtomicU64::new(0);

/// Batch-mode session state (built on Init).
struct WorkerState {
    backend: NativeBackend,
}

/// One resident window batch of a streaming session: its point count plus
/// the persistent RNG stream its sweeps draw from. The RNG is seeded by
/// the leader in global batch order and travels with the batch, so label
/// trajectories never depend on which worker owns it.
struct StreamBatch {
    id: u64,
    n: usize,
    rng: Xoshiro256pp,
}

/// Streaming-mode session state (built on StreamInit): this worker's slice
/// of the distributed window.
struct StreamState {
    prior: Prior,
    d: usize,
    threads: usize,
    kernel: AssignKernel,
    /// Cluster count of the most recent leader plan (labels index into it;
    /// grouped delta bundles are sized by it).
    k: usize,
    /// Window slice: resident points row-major with their live labels
    /// (capacity is unbounded worker-side — eviction is leader-decided).
    buffer: StreamBuffer,
    /// Resident batches, oldest first, aligned with the buffer's rows.
    batches: Vec<StreamBatch>,
}

/// What a connection is currently doing.
enum Session {
    Idle,
    Batch(WorkerState),
    Stream(StreamState),
}

/// `StreamIngest`: MAP-seed the batch under the leader's deterministic
/// posterior-mean plan, append it to the window slice, and report its
/// grouped stats delta.
fn stream_ingest(
    ss: &mut StreamState,
    batch_id: u64,
    seed: u64,
    params: StepParams,
    x: Vec<f64>,
) -> Message {
    let d = ss.d;
    if params.k() == 0 {
        return Message::Error("StreamIngest with an empty parameter snapshot".into());
    }
    if x.len() % d != 0 {
        return Message::Error(format!(
            "ingest batch length {} is not a multiple of the model dimension {d}",
            x.len()
        ));
    }
    if x.iter().any(|v| !v.is_finite()) {
        return Message::Error("ingest batch contains non-finite values".into());
    }
    let n = x.len() / d;
    if n == 0 {
        return Message::Error("StreamIngest with an empty batch".into());
    }
    let plan = params.plan();
    if plan.d != d {
        return Message::Error(format!(
            "StreamIngest parameter dimension {} != session dimension {d}",
            plan.d
        ));
    }
    let (z, zsub) = map_seed(&plan, &x, n, d, ss.threads);
    ss.k = params.k();
    let mut added = ss.prior.empty_bundle(ss.k);
    let sel: Vec<u32> = (0..n as u32).collect();
    fold_groups(&mut added, &x, d, &sel, &z, &zsub, true);
    ss.buffer.push(&x, &z, &zsub);
    ss.batches.push(StreamBatch { id: batch_id, n, rng: Xoshiro256pp::seed_from_u64(seed) });
    Message::StatsDelta(vec![BatchDelta { batch_id, removed: Vec::new(), added }])
}

/// `StreamSweep`: one restricted-Gibbs assignment pass over every resident
/// batch (one shard per batch, persistent per-batch RNG streams), replying
/// with canonical per-batch deltas of the moved points only.
fn stream_sweep(ss: &mut StreamState, params: StepParams) -> Message {
    let wlen = ss.buffer.len();
    if wlen == 0 {
        return Message::StatsDelta(Vec::new());
    }
    if params.k() == 0 {
        return Message::Error("StreamSweep with an empty parameter snapshot".into());
    }
    let d = ss.d;
    let plan = params.plan();
    if plan.d != d {
        return Message::Error(format!(
            "StreamSweep parameter dimension {} != session dimension {d}",
            plan.d
        ));
    }
    ss.k = params.k();
    // Zero-copy hand-off of the window values into the sweep's `Data`
    // (restored below — no early return may skip it).
    let data = Data::new(wlen, d, ss.buffer.take_values());
    // One shard per batch: shard boundaries are batch boundaries, so a
    // batch's labels and RNG stream are identical wherever it resides.
    let mut shards: Vec<Shard> = Vec::with_capacity(ss.batches.len());
    let mut start = 0usize;
    for b in ss.batches.iter_mut() {
        let range = start..start + b.n;
        let mut s =
            Shard::new(range.clone(), std::mem::replace(&mut b.rng, Xoshiro256pp::seed_from_u64(0)));
        s.z.copy_from_slice(&ss.buffer.labels()[range.clone()]);
        s.zsub.copy_from_slice(&ss.buffer.sub_labels()[range]);
        shards.push(s);
        start += b.n;
    }
    run_shards(&data, &mut shards, &plan, &ss.prior, ss.kernel, DEFAULT_TILE, ss.threads);
    // Per-batch canonical delta folds (single-threaded, batch-local
    // selection order — the leader replays them in global batch id order).
    let mut deltas = Vec::new();
    let mut new_z = Vec::with_capacity(wlen);
    let mut new_zsub = Vec::with_capacity(wlen);
    for (b, shard) in ss.batches.iter_mut().zip(shards) {
        let off = shard.range.start;
        let prev_z = &ss.buffer.labels()[shard.range.clone()];
        let prev_zsub = &ss.buffer.sub_labels()[shard.range.clone()];
        let changed: Vec<u32> = (0..b.n)
            .filter(|&i| prev_z[i] != shard.z[i] || prev_zsub[i] != shard.zsub[i])
            .map(|i| i as u32)
            .collect();
        if !changed.is_empty() {
            let values = &data.values[off * d..(off + b.n) * d];
            let mut removed = ss.prior.empty_bundle(ss.k);
            let mut added = ss.prior.empty_bundle(ss.k);
            fold_groups(&mut removed, values, d, &changed, prev_z, prev_zsub, true);
            fold_groups(&mut added, values, d, &changed, &shard.z, &shard.zsub, true);
            deltas.push(BatchDelta { batch_id: b.id, removed, added });
        }
        new_z.extend_from_slice(&shard.z);
        new_zsub.extend_from_slice(&shard.zsub);
        b.rng = shard.rng;
    }
    ss.buffer.restore_values(data.values);
    ss.buffer.set_labels(new_z, new_zsub);
    Message::StatsDelta(deltas)
}

/// Point offset of batch `idx` inside the window slice (batches are laid
/// out back-to-back in `buffer` in `batches` order).
fn batch_offset(batches: &[StreamBatch], idx: usize) -> usize {
    batches[..idx].iter().map(|b| b.n).sum()
}

/// `StreamEvict`: retire the named batches and report their current
/// grouped statistics so the leader can move the evidence from its window
/// accumulators into the frozen base. Eviction order is the leader's
/// global FIFO; after a rebalance the named batch may sit anywhere in this
/// worker's slice, so lookup is by id, not by front position.
fn stream_evict(ss: &mut StreamState, batch_ids: Vec<u64>) -> Message {
    let d = ss.d;
    let mut deltas = Vec::with_capacity(batch_ids.len());
    for id in batch_ids {
        let idx = match ss.batches.iter().position(|b| b.id == id) {
            Some(i) => i,
            None => return Message::Error(format!("evict of unknown batch {id}")),
        };
        let off = batch_offset(&ss.batches, idx);
        let b = ss.batches.remove(idx);
        let mut stats = ss.prior.empty_bundle(ss.k);
        let sel: Vec<u32> = (0..b.n as u32).collect();
        fold_groups(
            &mut stats,
            &ss.buffer.values()[off * d..(off + b.n) * d],
            d,
            &sel,
            &ss.buffer.labels()[off..off + b.n],
            &ss.buffer.sub_labels()[off..off + b.n],
            true,
        );
        ss.buffer.remove_span(off, b.n);
        deltas.push(BatchDelta { batch_id: b.id, removed: Vec::new(), added: stats });
    }
    Message::StatsDelta(deltas)
}

/// `StreamBatchState`: non-destructive per-batch state report (labels +
/// RNG). `batch_ids` empty = every resident batch, slice order. The
/// leader's periodic streaming checkpoint is the caller.
fn stream_batch_state(ss: &StreamState, batch_ids: Vec<u64>) -> Message {
    let ids: Vec<u64> = if batch_ids.is_empty() {
        ss.batches.iter().map(|b| b.id).collect()
    } else {
        batch_ids
    };
    let mut states = Vec::with_capacity(ids.len());
    for id in ids {
        let idx = match ss.batches.iter().position(|b| b.id == id) {
            Some(i) => i,
            None => return Message::Error(format!("batch state of unknown batch {id}")),
        };
        let off = batch_offset(&ss.batches, idx);
        let b = &ss.batches[idx];
        states.push(BatchState {
            batch_id: id,
            z: ss.buffer.labels()[off..off + b.n].to_vec(),
            zsub: ss.buffer.sub_labels()[off..off + b.n].to_vec(),
            rng: b.rng.state(),
        });
    }
    Message::StreamBatchStateReply(states)
}

/// `StreamRebalance`: detach the named batches from this slice and reply
/// with their state so the leader can `StreamRestore` them on another
/// worker. Values are dropped here (the leader retains them); labels and
/// RNG streams move verbatim, so a rebalance never forks the trajectory.
fn stream_rebalance(ss: &mut StreamState, batch_ids: Vec<u64>) -> Message {
    let mut states = Vec::with_capacity(batch_ids.len());
    for id in batch_ids {
        let idx = match ss.batches.iter().position(|b| b.id == id) {
            Some(i) => i,
            None => return Message::Error(format!("rebalance of unknown batch {id}")),
        };
        let off = batch_offset(&ss.batches, idx);
        let b = ss.batches.remove(idx);
        states.push(BatchState {
            batch_id: b.id,
            z: ss.buffer.labels()[off..off + b.n].to_vec(),
            zsub: ss.buffer.sub_labels()[off..off + b.n].to_vec(),
            rng: b.rng.state(),
        });
        ss.buffer.remove_span(off, b.n);
    }
    Message::StreamBatchStateReply(states)
}

/// `StreamRestore`: install one batch verbatim (values + labels + RNG, no
/// MAP seeding) — the receive side of rebalance/recovery and the worker
/// half of `dpmm stream --resume`.
fn stream_restore(
    ss: &mut StreamState,
    batch_id: u64,
    k: u32,
    x: Vec<f64>,
    z: Vec<u32>,
    zsub: Vec<u8>,
    rng: [u64; 4],
) -> Message {
    let d = ss.d;
    let n = z.len();
    if n == 0 {
        return Message::Error(format!("StreamRestore of empty batch {batch_id}"));
    }
    if k == 0 {
        return Message::Error(format!("StreamRestore batch {batch_id} with k = 0"));
    }
    if x.len() != n * d {
        return Message::Error(format!(
            "StreamRestore batch {batch_id}: {} values for {n} points of dimension {d}",
            x.len()
        ));
    }
    if x.iter().any(|v| !v.is_finite()) {
        return Message::Error(format!("StreamRestore batch {batch_id} has non-finite values"));
    }
    if z.iter().any(|&l| l >= k) || zsub.iter().any(|&s| s > 1) {
        return Message::Error(format!("StreamRestore batch {batch_id} has out-of-range labels"));
    }
    if ss.batches.iter().any(|b| b.id == batch_id) {
        return Message::Error(format!("StreamRestore of already-resident batch {batch_id}"));
    }
    ss.k = k as usize;
    ss.buffer.push(&x, &z, &zsub);
    ss.batches.push(StreamBatch { id: batch_id, n, rng: Xoshiro256pp::from_state(rng) });
    Message::Ack
}

/// Handle one verb. `buf` is the connection's reusable frame buffer (read
/// and write sides both go through it, so steady-state framing allocates
/// nothing). While the session is [`Session::Idle`] the read applies the
/// sessionless frame cap: a garbage or hostile length prefix on a
/// connection that never opened a session is rejected after two payload
/// bytes instead of driving a up-to-1-GiB allocation.
fn handle(stream: &mut TcpStream, session: &mut Session, buf: &mut Vec<u8>) -> Result<bool> {
    let idle = matches!(session, Session::Idle);
    let msg = read_message_into(stream, buf, idle)?;
    GENERATION.fetch_add(1, Ordering::Relaxed);
    crate::telemetry::catalog::worker_verbs_total().inc();
    let reply = match msg {
        // Supervision heartbeat (v4): valid in *any* session state — the
        // leader's supervisor probes on fresh connections that never open a
        // session, so the load figures come from the process-wide mirror.
        Message::Ping => Message::Pong {
            load: STREAM_POINTS.load(Ordering::Relaxed),
            depth: STREAM_DEPTH.load(Ordering::Relaxed),
            generation: GENERATION.load(Ordering::Relaxed),
        },
        // Telemetry scrape (v5): sessionless like Ping — `dpmm top` and
        // collectors probe the control socket on fresh connections.
        Message::Metrics => Message::MetricsReply(crate::telemetry::render()),
        Message::Init { d, prior, seed, threads, x } => {
            let d = d as usize;
            let n = x.len() / d.max(1);
            let data = Arc::new(Data::new(n, d, x));
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            // Workers run the same tiled assignment kernel as the local
            // CPU-threaded backend (shard.rs is the single hot path for
            // every tier); the default picks up DPMM_ASSIGN_KERNEL so the
            // scalar oracle can be selected per worker process.
            let config = NativeConfig {
                threads: (threads as usize).max(1),
                ..NativeConfig::default()
            };
            let backend = NativeBackend::new(data, prior, config, &mut rng);
            *session = Session::Batch(WorkerState { backend });
            Message::Ack
        }
        // StreamJoin is StreamInit for a live session: identical setup
        // worker-side; the distinct verb makes elastic joins explicit and
        // versioned on the wire.
        Message::StreamInit { d, prior, threads, kernel }
        | Message::StreamJoin { d, prior, threads, kernel } => {
            let d = d as usize;
            if d == 0 || prior.dim() != d {
                Message::Error(format!(
                    "StreamInit dimension {d} does not match the prior's {}",
                    prior.dim()
                ))
            } else {
                let kernel = match kernel {
                    0 => AssignKernel::from_env(),
                    1 => AssignKernel::Tiled,
                    3 => AssignKernel::DeviceEmu,
                    _ => AssignKernel::Scalar,
                };
                *session = Session::Stream(StreamState {
                    prior,
                    d,
                    threads: (threads as usize).max(1),
                    kernel,
                    k: 0,
                    buffer: StreamBuffer::new(d, usize::MAX),
                    batches: Vec::new(),
                });
                Message::Ack
            }
        }
        Message::StreamIngest { batch_id, seed, params, x } => match session {
            Session::Stream(ss) => stream_ingest(ss, batch_id, seed, params, x),
            _ => Message::Error("StreamIngest before StreamInit".into()),
        },
        Message::StreamSweep(params) => match session {
            Session::Stream(ss) => stream_sweep(ss, params),
            _ => Message::Error("StreamSweep before StreamInit".into()),
        },
        Message::StreamEvict { batch_ids } => match session {
            Session::Stream(ss) => stream_evict(ss, batch_ids),
            _ => Message::Error("StreamEvict before StreamInit".into()),
        },
        Message::StreamBatchState { batch_ids } => match session {
            Session::Stream(ss) => stream_batch_state(ss, batch_ids),
            _ => Message::Error("StreamBatchState before StreamInit".into()),
        },
        Message::StreamRebalance { batch_ids } => match session {
            Session::Stream(ss) => stream_rebalance(ss, batch_ids),
            _ => Message::Error("StreamRebalance before StreamInit".into()),
        },
        Message::StreamRestore { batch_id, k, x, z, zsub, rng } => match session {
            Session::Stream(ss) => stream_restore(ss, batch_id, k, x, z, zsub, rng),
            _ => Message::Error("StreamRestore before StreamInit".into()),
        },
        Message::Step(params) => match session {
            Session::Batch(ws) => match ws.backend.step(&params) {
                Ok(bundle) => Message::StatsReply(bundle.sub_stats),
                Err(e) => Message::Error(format!("step failed: {e}")),
            },
            _ => Message::Error("Step before Init".into()),
        },
        Message::ApplySplits(ops) => match session {
            Session::Batch(ws) => {
                ws.backend.apply_splits(&ops)?;
                Message::Ack
            }
            _ => Message::Error("ApplySplits before Init".into()),
        },
        Message::ApplyMerges(ops) => match session {
            Session::Batch(ws) => {
                ws.backend.apply_merges(&ops)?;
                Message::Ack
            }
            _ => Message::Error("ApplyMerges before Init".into()),
        },
        Message::Remap(map) => match session {
            Session::Batch(ws) => {
                let map: Vec<Option<usize>> =
                    map.into_iter().map(|m| m.map(|v| v as usize)).collect();
                ws.backend.remap(&map)?;
                Message::Ack
            }
            _ => Message::Error("Remap before Init".into()),
        },
        Message::RandomizeLabels { k } => match session {
            Session::Batch(ws) => {
                ws.backend.randomize_labels(k as usize);
                Message::Ack
            }
            _ => Message::Error("RandomizeLabels before Init".into()),
        },
        Message::GetLabels => match session {
            Session::Batch(ws) => {
                Message::Labels(ws.backend.labels()?.into_iter().map(|l| l as u32).collect())
            }
            _ => Message::Error("GetLabels before Init".into()),
        },
        Message::Shutdown => {
            write_message_into(stream, &Message::Ack, buf)?;
            return Ok(false);
        }
        other => Message::Error(format!("unexpected message {other:?}")),
    };
    if let Session::Stream(ss) = &*session {
        STREAM_POINTS.store(ss.buffer.len() as u64, Ordering::Relaxed);
        STREAM_DEPTH.store(ss.batches.len() as u64, Ordering::Relaxed);
        crate::telemetry::catalog::stream_window_points().set(ss.buffer.len() as f64);
        crate::telemetry::catalog::stream_window_batches().set(ss.batches.len() as f64);
    }
    write_message_into(stream, &reply, buf)?;
    Ok(true)
}

/// Serve a single leader connection to completion (Shutdown or EOF).
pub fn serve_connection(mut stream: TcpStream) -> Result<()> {
    // NODELAY + I/O timeouts: a leader that dies mid-protocol unblocks the
    // worker within one timeout instead of wedging it forever.
    super::wire::configure_stream(&stream).ok();
    let mut session = Session::Idle;
    let mut buf = Vec::new();
    loop {
        match handle(&mut stream, &mut session, &mut buf) {
            Ok(true) => continue,
            Ok(false) => return Ok(()),
            Err(e) => {
                // EOF = leader went away; anything else is a real error.
                if e.downcast_ref::<std::io::Error>()
                    .map(|io| io.kind() == std::io::ErrorKind::UnexpectedEof)
                    .unwrap_or(false)
                {
                    return Ok(());
                }
                return Err(e);
            }
        }
    }
}

/// Bind and serve connections forever (the `dpmm worker` CLI entrypoint).
/// One session per connection, each on its own thread: the paper's
/// topology has exactly one master, but since PROTO v4 the leader's
/// *supervisor* opens short heartbeat probes concurrently with the
/// long-lived fit/stream session, so connections must not queue behind
/// each other.
pub fn serve(addr: &str) -> Result<()> {
    crate::telemetry::catalog::register_defaults();
    let listener =
        TcpListener::bind(addr).with_context(|| format!("worker bind {addr}"))?;
    eprintln!("dpmm worker listening on {}", listener.local_addr()?);
    for stream in listener.incoming() {
        // A leader that times out or dies mid-protocol ends its connection
        // (I/O timeout via wire::configure_stream) but must not take the
        // worker process down — stay up for the next leader.
        let stream = stream?;
        std::thread::spawn(move || {
            if let Err(e) = serve_connection(stream) {
                eprintln!("worker: leader connection ended with error: {e:#}");
            }
        });
    }
    Ok(())
}

/// Spawn an in-process worker on an ephemeral port; returns its address.
/// Used by tests, examples, and `--workers N` convenience mode (the paper's
/// multi-machine topology collapsed onto localhost). The worker serves
/// whichever session kind — batch fit or streaming — the leader opens, and
/// like [`serve`] handles each connection on its own thread so heartbeat
/// probes are answered while a session is live.
pub fn spawn_local() -> Result<String> {
    crate::telemetry::catalog::register_defaults();
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { return };
            std::thread::spawn(move || {
                if let Err(e) = serve_connection(stream) {
                    eprintln!("worker error: {e}");
                }
            });
        }
    });
    Ok(addr)
}

/// Spawn an in-process worker that serves exactly `die_after` leader
/// requests through a frame-level proxy in front of a real
/// [`spawn_local`] worker, then drops both connections — a deterministic
/// "death mid-session" at request granularity, so two runs with the same
/// schedule observe the identical failure point. Since PROTO v4 this is a
/// thin wrapper over the scripted [`super::fault::FaultProxy`] harness
/// (plan: forward `die_after` pairs, then [`super::fault::FaultAction::Drop`]);
/// the recovery tests and `benches/stream_recovery.rs` pin the elastic
/// leader's contracts against it (see docs/DETERMINISM.md).
pub fn spawn_local_dying(die_after: usize) -> Result<String> {
    use super::fault::{FaultAction, FaultProxy};
    let upstream = spawn_local()?;
    let proxy =
        FaultProxy::spawn(upstream, vec![FaultAction::Forward(die_after), FaultAction::Drop])?;
    Ok(proxy.addr().to_string())
}
