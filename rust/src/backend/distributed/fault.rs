//! Deterministic fault-injection harness: a scripted frame-level TCP proxy
//! placed in front of a real worker.
//!
//! [`FaultProxy`] generalizes the ad-hoc "die after N requests" proxy the
//! recovery tests used through PR 5 (`spawn_local_dying` is now a thin
//! wrapper over it). A proxy is driven by a [`FaultPlan`] — an ordered
//! script of [`FaultAction`]s consumed left to right across *all*
//! connections it accepts — so two runs with the same plan and the same
//! leader schedule observe the identical failure point. Once the plan is
//! exhausted the proxy forwards transparently forever, which is what makes
//! "refuse twice, then behave" bitwise-comparable to a fault-free run.
//!
//! Faults are injected at frame granularity (`[u32 length][body]`, see
//! [`super::wire`]), not byte granularity: the protocol's failure
//! classification (transient vs fatal, `wire::classify_error`) is defined
//! over whole-frame outcomes, and frame boundaries are the only points the
//! leader's retry layer can safely resume from.
//!
//! Used by `tests/integration_stream_supervision.rs`,
//! `tests/integration_stream_recovery.rs` (via `spawn_local_dying`) and
//! `benches/chaos_recovery.rs`.

use super::wire::{read_frame, write_frame};
use anyhow::Result;
use std::collections::VecDeque;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One scripted step of a [`FaultPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Accept-then-instantly-close the next `k` downstream connections.
    /// The dialer's connect succeeds but its first request dies with a
    /// reset/EOF — classified transient, exactly like a true
    /// `ECONNREFUSED` (which a bound listener cannot produce on demand).
    /// Consumed at connection open; ignored while a connection is live.
    RefuseConnect(usize),
    /// Forward the next `n` request/reply frame pairs transparently.
    Forward(usize),
    /// Sleep this many milliseconds before forwarding the next request
    /// upstream (a slow link: the dialer's read blocks for the duration).
    Delay(u64),
    /// Forward the next request, then cut the connection halfway through
    /// writing the reply frame: the dialer sees a mid-frame EOF
    /// (transient), never a decodable-but-corrupt payload (fatal).
    TruncateFrame,
    /// Kill the proxy: drop the live connection mid-session, stop
    /// accepting, and refuse everything thereafter — a worker crash. This
    /// is `spawn_local_dying`'s terminal action.
    Drop,
}

/// An ordered fault script, consumed left to right across a proxy's
/// lifetime. Empty plan = transparent proxy.
pub type FaultPlan = Vec<FaultAction>;

/// What the shared plan says to do with the next frame pair.
enum Step {
    Forward,
    Delay(u64),
    Truncate,
}

/// Handle to a running scripted proxy. Dropping the handle does *not* stop
/// the proxy (plans usually outlive the spawning scope in tests); call
/// [`FaultProxy::kill`] to silence it deterministically.
pub struct FaultProxy {
    addr: String,
    killed: Arc<AtomicBool>,
}

impl FaultProxy {
    /// Bind an ephemeral port and proxy every accepted connection to
    /// `upstream` under `plan`. Each downstream connection gets its own
    /// fresh upstream connection (sessions are per-connection worker-side).
    pub fn spawn(upstream: String, plan: FaultPlan) -> Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let killed = Arc::new(AtomicBool::new(false));
        let plan = Arc::new(Mutex::new(VecDeque::from(plan)));
        {
            let killed = Arc::clone(&killed);
            std::thread::spawn(move || {
                for down in listener.incoming() {
                    let Ok(down) = down else { return };
                    if killed.load(Ordering::SeqCst) {
                        // Listener drops on return: every later connect is
                        // refused outright — the proxy is dead.
                        return;
                    }
                    let upstream = upstream.clone();
                    let plan = Arc::clone(&plan);
                    let killed = Arc::clone(&killed);
                    std::thread::spawn(move || {
                        let _ = run_connection(down, &upstream, &plan, &killed);
                    });
                }
            });
        }
        Ok(FaultProxy { addr, killed })
    }

    /// Address leaders should dial instead of the real worker's.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Silence the proxy from the outside — the deterministic trigger for
    /// "worker went dark": the accept loop exits (dropping the listener, so
    /// heartbeat probes get connection-refused) and live forwarders stop at
    /// their next frame boundary.
    pub fn kill(&self) {
        self.killed.store(true, Ordering::SeqCst);
        // Wake the accept loop so the listener drops promptly.
        let _ = TcpStream::connect(&self.addr);
    }
}

fn run_connection(
    mut down: TcpStream,
    upstream: &str,
    plan: &Mutex<VecDeque<FaultAction>>,
    killed: &AtomicBool,
) -> Result<()> {
    // Connection-open actions first, before touching the upstream.
    {
        let mut g = plan.lock().unwrap();
        match g.front_mut() {
            Some(FaultAction::RefuseConnect(k)) => {
                *k -= 1;
                if *k == 0 {
                    g.pop_front();
                }
                return Ok(()); // `down` drops: connect succeeded, session dies instantly
            }
            Some(FaultAction::Drop) => {
                g.pop_front();
                killed.store(true, Ordering::SeqCst);
                return Ok(());
            }
            _ => {}
        }
    }
    let mut up = TcpStream::connect(upstream)?;
    loop {
        if killed.load(Ordering::SeqCst) {
            return Ok(()); // both sockets drop mid-session
        }
        // Select (and consume) the action governing the next frame pair
        // *before* reading it, so `Drop` right after `Forward(n)` kills the
        // session immediately after the n-th reply — not one request later.
        let step = {
            let mut g = plan.lock().unwrap();
            match g.front_mut() {
                None | Some(FaultAction::RefuseConnect(_)) => Step::Forward,
                Some(FaultAction::Forward(n)) => {
                    *n -= 1;
                    if *n == 0 {
                        g.pop_front();
                    }
                    Step::Forward
                }
                Some(FaultAction::Delay(ms)) => {
                    let ms = *ms;
                    g.pop_front();
                    Step::Delay(ms)
                }
                Some(FaultAction::TruncateFrame) => {
                    g.pop_front();
                    Step::Truncate
                }
                Some(FaultAction::Drop) => {
                    g.pop_front();
                    killed.store(true, Ordering::SeqCst);
                    return Ok(());
                }
            }
        };
        let Ok(req) = read_frame(&mut down) else {
            return Ok(()); // downstream went away; plan state stays put
        };
        if let Step::Delay(ms) = step {
            std::thread::sleep(Duration::from_millis(ms));
        }
        write_frame(&mut up, &req)?;
        let reply = read_frame(&mut up)?;
        match step {
            Step::Truncate => {
                // Advertise the full reply but deliver only half of it,
                // then cut: downstream reads a mid-frame EOF.
                down.write_all(&(reply.len() as u32).to_le_bytes())?;
                down.write_all(&reply[..reply.len() / 2])?;
                return Ok(());
            }
            _ => write_frame(&mut down, &reply)?,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::wire::{request, Message};
    use super::super::worker::spawn_local;
    use super::*;

    #[test]
    fn empty_plan_is_transparent_and_serves_many_connections() {
        let proxy = FaultProxy::spawn(spawn_local().unwrap(), Vec::new()).unwrap();
        for _ in 0..3 {
            let mut s = TcpStream::connect(proxy.addr()).unwrap();
            match request(&mut s, &Message::Ping).unwrap() {
                Message::Pong { .. } => {}
                other => panic!("unexpected reply {other:?}"),
            }
        }
    }

    #[test]
    fn refuse_connect_consumes_then_behaves() {
        let proxy =
            FaultProxy::spawn(spawn_local().unwrap(), vec![FaultAction::RefuseConnect(2)])
                .unwrap();
        for _ in 0..2 {
            let mut s = TcpStream::connect(proxy.addr()).unwrap();
            assert!(request(&mut s, &Message::Ping).is_err());
        }
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        assert!(matches!(request(&mut s, &Message::Ping).unwrap(), Message::Pong { .. }));
    }

    #[test]
    fn truncated_reply_reads_as_mid_frame_eof() {
        let proxy =
            FaultProxy::spawn(spawn_local().unwrap(), vec![FaultAction::TruncateFrame]).unwrap();
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        let err = request(&mut s, &Message::Ping).unwrap_err();
        assert!(
            matches!(
                super::super::wire::classify_error(&err),
                super::super::wire::FaultClass::Transient
            ),
            "truncated frame should classify transient: {err:#}"
        );
    }

    #[test]
    fn kill_silences_future_connections() {
        let proxy = FaultProxy::spawn(spawn_local().unwrap(), Vec::new()).unwrap();
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        assert!(matches!(request(&mut s, &Message::Ping).unwrap(), Message::Pong { .. }));
        proxy.kill();
        // The accept loop exits asynchronously; poll until connects are
        // refused (or an accepted-then-dropped socket fails its request).
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match TcpStream::connect(proxy.addr()) {
                Err(_) => break,
                Ok(mut s) => {
                    if request(&mut s, &Message::Ping).is_err() {
                        break;
                    }
                }
            }
            assert!(std::time::Instant::now() < deadline, "proxy never went silent");
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}
