//! The per-shard restricted-Gibbs kernel shared by the native and
//! distributed backends (workers run exactly this code on their chunk).
//!
//! For every point: sample z_i ∝ π_k f(x_i; θ_k) over instantiated clusters
//! (Eq. 18), then z̄_i over the assigned cluster's two sub-clusters (Eq. 19),
//! and accumulate sufficient statistics into the sub-cluster accumulators
//! (cluster statistics are recovered as the sum of the two sub-clusters,
//! halving the accumulation work — the dominant O(N·d²) term for Gaussians).
//!
//! Two implementations of the same sampler:
//!
//! * [`shard_step_tiled`] — the production kernel. Points are processed in
//!   tiles of T (default [`DEFAULT_TILE`]); for each instantiated cluster
//!   the whole tile's log-likelihoods are one blocked triangular GEMM
//!   `Y = W_k·X_tileᵀ` against a precomputed affine offset `b_k = W_k·μ_k`
//!   (`loglik = c_k − ½‖y − b_k‖²`, no per-point diff vector), written into
//!   a column-major `[K × T]` score matrix the categorical draw scans with
//!   unit stride. Statistics accumulate at tile granularity via grouped
//!   rank-T updates, and the sub-cluster step (f) is batched per cluster
//!   over the tile's member columns.
//! * [`shard_step_scalar`] — the one-point-at-a-time correctness oracle,
//!   kept behind [`AssignKernel`] (`DPMM_ASSIGN_KERNEL=scalar`).
//!
//! Both paths draw exactly two uniforms per point in the same stream order
//! and share bitwise-identical score arithmetic (see [`crate::linalg`]'s
//! FP-determinism contract), so they produce identical label and sub-label
//! sequences under a fixed seed. Sufficient statistics agree to FP rounding
//! (the tiled path reduces tile-local partial sums first). See
//! EXPERIMENTS.md §Perf for the design and measured speedups.

use super::StatsBundle;
use crate::datagen::Data;
use crate::linalg::{dot_accumulate_tile, lower_affine_sqnorm, transpose_tile};
use crate::model::{LEFT, RIGHT};
use crate::rng::{Rng, Xoshiro256pp};
use crate::sampler::{KernelDesc, MergeOp, SplitOp, StepPlan};
use crate::stats::Prior;
use std::ops::Range;

/// Default assignment-kernel tile width (points per tile). Sized so a
/// d ≤ 64 tile (`d × T` doubles) plus the score panel stays L1/L2-resident.
pub const DEFAULT_TILE: usize = 128;

/// Which assignment executor a backend runs. The scalar path is the
/// correctness oracle for the other two (identical labels, same seed; see
/// [`crate::backend::executor`] and `tests/prop_kernel_equiv.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignKernel {
    /// Batched whitened-GEMM tile kernel (production default).
    Tiled,
    /// One-point-at-a-time oracle (`DPMM_ASSIGN_KERNEL=scalar`).
    Scalar,
    /// Multi-stream device-emulation executor: staged
    /// upload/launch/download over stream-per-block queues, modeling the
    /// paper's GPU execution (`DPMM_ASSIGN_KERNEL=device`).
    DeviceEmu,
}

impl AssignKernel {
    /// Resolve from the `DPMM_ASSIGN_KERNEL` environment variable
    /// (`scalar` selects the oracle, `device`/`device-emu` the
    /// device-emulation executor, `tiled`/unset the production kernel;
    /// case-insensitive). An unrecognized value falls back to tiled with a
    /// stderr warning rather than silently running the wrong kernel during
    /// an intended oracle verification.
    pub fn from_env() -> Self {
        match std::env::var("DPMM_ASSIGN_KERNEL") {
            Ok(v) if v.eq_ignore_ascii_case("scalar") => AssignKernel::Scalar,
            Ok(v)
                if v.eq_ignore_ascii_case("device")
                    || v.eq_ignore_ascii_case("device-emu")
                    || v.eq_ignore_ascii_case("device_emu") =>
            {
                AssignKernel::DeviceEmu
            }
            Ok(v) if v.is_empty() || v.eq_ignore_ascii_case("tiled") => AssignKernel::Tiled,
            Ok(v) => {
                eprintln!(
                    "warning: unrecognized DPMM_ASSIGN_KERNEL='{v}' (expected 'tiled', \
                     'scalar', or 'device'); using the tiled kernel"
                );
                AssignKernel::Tiled
            }
            Err(_) => AssignKernel::Tiled,
        }
    }
}

/// One contiguous chunk of the dataset with its labels and private RNG.
#[derive(Debug, Clone)]
pub struct Shard {
    pub range: Range<usize>,
    /// Cluster label per point (index into the coordinator's cluster list).
    pub z: Vec<u32>,
    /// Sub-cluster label per point (LEFT/RIGHT).
    pub zsub: Vec<u8>,
    pub rng: Xoshiro256pp,
}

impl Shard {
    pub fn new(range: Range<usize>, rng: Xoshiro256pp) -> Self {
        let n = range.len();
        Self { range, z: vec![0; n], zsub: vec![0; n], rng }
    }

    pub fn len(&self) -> usize {
        self.range.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Map `f` over every shard from a scoped worker pool and collect the
/// results in shard order. Shards are divided into contiguous `chunks_mut`
/// slices, so each thread owns an exclusive `&mut [Shard]` — no raw-pointer
/// cells, plain safe borrows. Shared by the native backend's per-iteration
/// passes and the streaming fitter's window sweeps (one definition of the
/// chunking math, not two drifting copies).
pub fn map_shards_mut<R, F>(shards: &mut [Shard], threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(&mut Shard) -> R + Sync,
{
    if shards.is_empty() {
        return Vec::new();
    }
    let threads = threads.clamp(1, shards.len());
    let chunk = shards.len().div_ceil(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .chunks_mut(chunk)
            .map(|group| {
                let f = &f;
                scope.spawn(move || group.iter_mut().map(f).collect::<Vec<R>>())
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("shard thread panicked"))
            .collect()
    })
}

/// Tile-granular scratch reused across tiles (no per-tile allocation in the
/// hot loop; see EXPERIMENTS.md §Perf).
struct TileScratch {
    /// Feature-major tile: `xt[i·T + t]` = feature `i` of tile point `t`.
    xt: Vec<f64>,
    /// Column-major `[K × T]` score matrix: `scores[t·K + c]`.
    scores: Vec<f64>,
    /// Current GEMM output row (length T).
    y: Vec<f64>,
    /// Per-point reduction accumulator (length T).
    maha: Vec<f64>,
    /// Pre-drawn uniforms, cluster draw per point (length T).
    u_cl: Vec<f64>,
    /// Pre-drawn uniforms, sub-cluster draw per point (length T).
    u_sub: Vec<f64>,
    /// Tile-local member indices per cluster (grouping for steps (f)+stats).
    members: Vec<Vec<u32>>,
    /// Gathered member columns (feature-major, stride = member count).
    gather: Vec<f64>,
    /// Sub-cluster weighted log-likelihoods over members (left / right).
    lw_l: Vec<f64>,
    lw_r: Vec<f64>,
    /// Member-local index lists per drawn sub-cluster.
    side: [Vec<u32>; 2],
}

impl TileScratch {
    fn new(k: usize, d: usize, tile: usize) -> Self {
        Self {
            xt: vec![0.0; d * tile],
            scores: vec![0.0; k * tile],
            y: vec![0.0; tile],
            maha: vec![0.0; tile],
            u_cl: vec![0.0; tile],
            u_sub: vec![0.0; tile],
            members: (0..k).map(|_| Vec::with_capacity(tile)).collect(),
            gather: vec![0.0; d * tile],
            lw_l: vec![0.0; tile],
            lw_r: vec![0.0; tile],
            side: [Vec::with_capacity(tile), Vec::with_capacity(tile)],
        }
    }
}

/// Run steps (e)/(f) + statistics on one shard with the default kernel and
/// tile width. Labels are written in place; the returned bundle holds this
/// shard's contribution.
pub fn shard_step(data: &Data, shard: &mut Shard, plan: &StepPlan, prior: &Prior) -> StatsBundle {
    shard_step_tiled(data, shard, plan, prior, DEFAULT_TILE)
}

/// Tiled assignment kernel (see module docs for the design).
pub fn shard_step_tiled(
    data: &Data,
    shard: &mut Shard,
    plan: &StepPlan,
    prior: &Prior,
    tile: usize,
) -> StatsBundle {
    let k = plan.k();
    let d = plan.d;
    debug_assert_eq!(d, data.d);
    let tile = tile.max(1);
    let n = shard.len();
    let mut bundle = StatsBundle::empty(prior, k);
    let mut scratch = TileScratch::new(k, d, tile);
    let TileScratch { xt, scores, y, maha, u_cl, u_sub, members, gather, lw_l, lw_r, side } =
        &mut scratch;
    // Coarse-ticked phase timing: clock reads at tile boundaries only
    // (never per point), and only when telemetry is enabled — the stripped
    // path pays a single flag load per shard call. Durations accumulate
    // locally and hit the histograms once at the end.
    let timing = crate::telemetry::enabled();
    let mut t_score = std::time::Duration::ZERO;
    let mut t_draw = std::time::Duration::ZERO;
    let mut t_stats = std::time::Duration::ZERO;
    let mut tiles: u64 = 0;
    let mut start = 0;
    while start < n {
        let m = tile.min(n - start);
        let base = shard.range.start + start;
        // Pre-draw the tile's uniforms in scalar stream order (cluster draw
        // then sub draw, per point): both kernels consume exactly two
        // uniforms per point, so the streams stay aligned and the draws are
        // value-identical to the scalar oracle's interleaved calls.
        for t in 0..m {
            u_cl[t] = shard.rng.next_f64();
            u_sub[t] = shard.rng.next_f64();
        }
        transpose_tile(&data.values[base * d..(base + m) * d], d, m, xt);
        let mut mark = if timing { Some(std::time::Instant::now()) } else { None };
        // Step (e), batched: one blocked triangular GEMM per cluster fills
        // the tile's score column with unit-stride writes per point.
        for (c, desc) in plan.clusters.iter().enumerate() {
            match desc {
                KernelDesc::Gauss { w, b, c: ck } => {
                    lower_affine_sqnorm(w, d, b, xt, m, y, maha);
                    for t in 0..m {
                        scores[t * k + c] = ck - 0.5 * maha[t];
                    }
                }
                KernelDesc::Mult { log_theta, c: ck } => {
                    dot_accumulate_tile(log_theta, xt, m, maha);
                    for t in 0..m {
                        scores[t * k + c] = ck + maha[t];
                    }
                }
            }
        }
        if let Some(t0) = mark {
            let now = std::time::Instant::now();
            t_score += now - t0;
            mark = Some(now);
        }
        // Categorical draw per point: a stable exp-scan over the point's
        // unit-stride score column (one uniform + K exps; the equivalent
        // Gumbel-argmax costs K draws + 2K logs and dominated the profile,
        // see EXPERIMENTS.md §Perf).
        for t in 0..m {
            let col = &mut scores[t * k..(t + 1) * k];
            let mut best = f64::NEG_INFINITY;
            for &lw in col.iter() {
                if lw > best {
                    best = lw;
                }
            }
            let mut total = 0.0;
            for e in col.iter_mut() {
                let gap = *e - best;
                // exp(−36) ≈ 2e-16: below one ULP of the running sum, so the
                // cluster can't be drawn — skip the transcendental.
                let v = if gap < -36.0 { 0.0 } else { gap.exp() };
                *e = v;
                total += v;
            }
            let mut tgt = u_cl[t] * total;
            let mut zi = k - 1;
            for (c, &e) in col.iter().enumerate() {
                tgt -= e;
                if tgt < 0.0 {
                    zi = c;
                    break;
                }
            }
            shard.z[start + t] = zi as u32;
            members[zi].push(t as u32);
        }
        if let Some(t0) = mark {
            let now = std::time::Instant::now();
            t_draw += now - t0;
            mark = Some(now);
        }
        // Step (f) + statistics, batched per cluster over member columns.
        for (c, mem) in members.iter_mut().enumerate() {
            if mem.is_empty() {
                continue;
            }
            let mc = mem.len();
            // Gather member columns into a compact feature-major panel.
            for i in 0..d {
                let src = &xt[i * m..i * m + m];
                let dst = &mut gather[i * mc..(i + 1) * mc];
                for (g, &t) in dst.iter_mut().zip(mem.iter()) {
                    *g = src[t as usize];
                }
            }
            // Two-way sub-competition: one batched kernel per side.
            for (h, out) in [(LEFT, &mut *lw_l), (RIGHT, &mut *lw_r)] {
                match &plan.sub[c][h] {
                    KernelDesc::Gauss { w, b, c: ck } => {
                        lower_affine_sqnorm(w, d, b, gather, mc, y, maha);
                        for (o, &mh) in out[..mc].iter_mut().zip(maha.iter()) {
                            *o = ck - 0.5 * mh;
                        }
                    }
                    KernelDesc::Mult { log_theta, c: ck } => {
                        dot_accumulate_tile(log_theta, gather, mc, maha);
                        for (o, &acc) in out[..mc].iter_mut().zip(maha.iter()) {
                            *o = ck + acc;
                        }
                    }
                }
            }
            side[0].clear();
            side[1].clear();
            for (idx, &t) in mem.iter().enumerate() {
                // P(right) = 1 / (1 + exp(lw_l − lw_r))
                let p_right = 1.0 / (1.0 + (lw_l[idx] - lw_r[idx]).exp());
                let hi = usize::from(u_sub[t as usize] < p_right);
                shard.zsub[start + t as usize] = hi as u8;
                side[hi].push(idx as u32);
            }
            // Grouped rank-T statistics update per (cluster, sub-cluster):
            // one pass over each accumulator per tile instead of one
            // `add_outer` per point.
            for (h, sel) in side.iter().enumerate() {
                if !sel.is_empty() {
                    bundle.sub_stats[c][h].add_cols(gather, mc, sel);
                }
            }
            mem.clear();
        }
        if let Some(t0) = mark {
            t_stats += t0.elapsed();
        }
        tiles += 1;
        start += m;
    }
    if timing {
        use crate::telemetry::catalog;
        catalog::sweep_phase("score").observe(t_score.as_secs_f64());
        catalog::sweep_phase("draw").observe(t_draw.as_secs_f64());
        catalog::sweep_phase("stats_fold").observe(t_stats.as_secs_f64());
        catalog::gemm_seconds().observe(t_score.as_secs_f64());
        catalog::gemm_tiles_total().add(tiles);
    }
    bundle
}

/// One-point-at-a-time correctness oracle for [`shard_step_tiled`]:
/// identical label/sub-label sequences under the same seed (see module
/// docs), selectable via [`AssignKernel::Scalar`].
pub fn shard_step_scalar(
    data: &Data,
    shard: &mut Shard,
    plan: &StepPlan,
    prior: &Prior,
) -> StatsBundle {
    let k = plan.k();
    let mut bundle = StatsBundle::empty(prior, k);
    let mut loglik = vec![0.0; k];
    for (local, i) in shard.range.clone().enumerate() {
        let x = data.row(i);
        // Step (e): z_i ∝ π_k f(x; θ_k) — categorical draw via a stable
        // exp-scan (one RNG draw + K exps).
        let mut best = f64::NEG_INFINITY;
        for (c, desc) in plan.clusters.iter().enumerate() {
            let lw = desc.loglik(x);
            loglik[c] = lw;
            if lw > best {
                best = lw;
            }
        }
        let mut total = 0.0;
        for e in loglik.iter_mut() {
            let gap = *e - best;
            let v = if gap < -36.0 { 0.0 } else { gap.exp() };
            *e = v;
            total += v;
        }
        let mut t = shard.rng.next_f64() * total;
        let mut zi = k - 1;
        for (c, &e) in loglik.iter().enumerate() {
            t -= e;
            if t < 0.0 {
                zi = c;
                break;
            }
        }
        // Step (f): z̄_i over the assigned cluster's sub-clusters — a
        // two-way categorical from the log-odds.
        let sub_lw_l = plan.sub[zi][LEFT].loglik(x);
        let sub_lw_r = plan.sub[zi][RIGHT].loglik(x);
        // P(right) = 1 / (1 + exp(lw_l − lw_r))
        let p_right = 1.0 / (1.0 + (sub_lw_l - sub_lw_r).exp());
        let hi = usize::from(shard.rng.next_f64() < p_right);
        shard.z[local] = zi as u32;
        shard.zsub[local] = hi as u8;
        bundle.sub_stats[zi][hi].add(x);
    }
    bundle
}

/// Apply accepted splits to a shard's labels (mirrors
/// [`crate::sampler::apply_split`]'s state change).
///
/// Single O(N) pass with an op lookup table regardless of the number of
/// accepted splits: targets are distinct clusters of the pre-split state and
/// new indices are fresh (≥ pre-split K), so ops never chain and per-point
/// application order doesn't matter. Sub-label re-randomization draws in
/// point order (not op-major order as the old O(ops·N) loop did) — a
/// different but equally valid stream of fresh coin flips.
pub fn shard_apply_splits(shard: &mut Shard, ops: &[SplitOp]) {
    if ops.is_empty() {
        return;
    }
    let max_target = ops.iter().map(|op| op.target).max().unwrap();
    let mut table: Vec<Option<u32>> = vec![None; max_target + 1];
    for op in ops {
        debug_assert!(table[op.target].is_none(), "split targets must be distinct");
        debug_assert!(op.new_index > max_target, "split indices must be fresh");
        table[op.target] = Some(op.new_index as u32);
    }
    for local in 0..shard.len() {
        let zi = shard.z[local] as usize;
        if let Some(Some(new_index)) = table.get(zi).copied() {
            if shard.zsub[local] as usize == RIGHT {
                shard.z[local] = new_index;
            }
            // Fresh sub-assignment for the next sweep (children start
            // with random sub-clusters, like the reference impl).
            shard.zsub[local] = (shard.rng.next_u64() & 1) as u8;
        }
    }
}

/// Role a cluster plays in this iteration's accepted merges.
#[derive(Clone, Copy)]
enum MergeRole {
    Keep,
    Absorb(u32),
}

/// Apply accepted merges to a shard's labels.
///
/// Single O(N) pass with a role lookup table: merge ops are pairwise
/// disjoint (no cluster appears in two ops — enforced by
/// [`crate::sampler::propose_merges`]'s conflict resolution), so the table
/// is exactly equivalent to applying the ops in sequence.
pub fn shard_apply_merges(shard: &mut Shard, ops: &[MergeOp]) {
    if ops.is_empty() {
        return;
    }
    let max = ops.iter().map(|op| op.keep.max(op.absorb)).max().unwrap();
    let mut table: Vec<Option<MergeRole>> = vec![None; max + 1];
    for op in ops {
        debug_assert!(
            table[op.keep].is_none() && table[op.absorb].is_none(),
            "merge ops must be pairwise disjoint"
        );
        table[op.keep] = Some(MergeRole::Keep);
        table[op.absorb] = Some(MergeRole::Absorb(op.keep as u32));
    }
    for local in 0..shard.len() {
        match table.get(shard.z[local] as usize).copied().flatten() {
            Some(MergeRole::Keep) => shard.zsub[local] = LEFT as u8,
            Some(MergeRole::Absorb(keep)) => {
                shard.z[local] = keep;
                shard.zsub[local] = RIGHT as u8;
            }
            None => {}
        }
    }
}

/// Apply a removal remap to a shard's labels.
pub fn shard_remap(shard: &mut Shard, map: &[Option<usize>]) {
    for local in 0..shard.len() {
        let old = shard.z[local] as usize;
        match map.get(old).copied().flatten() {
            Some(new) => shard.z[local] = new as u32,
            None => {
                // Point's cluster vanished (should only happen for empty
                // clusters — impossible — or after external surgery).
                // Reassign to cluster 0 defensively.
                shard.z[local] = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DpmmState;
    use crate::sampler::StepParams;
    use crate::stats::NiwPrior;

    fn two_blob_data() -> Data {
        // 40 points at (−10, 0), 40 at (10, 0) with tiny deterministic jitter.
        let mut values = Vec::new();
        for i in 0..40 {
            values.push(-10.0 + 0.01 * i as f64);
            values.push(0.0);
        }
        for i in 0..40 {
            values.push(10.0 + 0.01 * i as f64);
            values.push(0.0);
        }
        Data::new(80, 2, values)
    }

    fn params_two_clusters() -> (StepParams, Prior) {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut state = DpmmState::new(1.0, prior.clone(), 2, 80, &mut rng);
        // Hand-place the clusters on the blobs.
        for (k, center) in [(-10.0f64, 0), (10.0, 1)].map(|(c, k)| (k, c)) {
            let mut s = prior.empty_stats();
            for j in 0..50 {
                s.add(&[center + 0.01 * j as f64, 0.0]);
            }
            state.clusters[k].stats = s.clone();
            state.clusters[k].sub_stats = [s.clone(), s];
            state.clusters[k].params = prior.mean_params(&state.clusters[k].stats);
            state.clusters[k].sub_params = [
                prior.mean_params(&state.clusters[k].sub_stats[0]),
                prior.mean_params(&state.clusters[k].sub_stats[1]),
            ];
            state.clusters[k].weight = 0.5;
        }
        (StepParams::snapshot(&state), prior)
    }

    #[test]
    fn step_assigns_points_to_nearest_cluster() {
        let data = two_blob_data();
        let (params, prior) = params_two_clusters();
        let plan = params.plan();
        let mut shard = Shard::new(0..80, Xoshiro256pp::seed_from_u64(9));
        let bundle = shard_step(&data, &mut shard, &plan, &prior);
        for local in 0..40 {
            assert_eq!(shard.z[local], 0, "left blob must go to cluster 0");
        }
        for local in 40..80 {
            assert_eq!(shard.z[local], 1);
        }
        let cs = bundle.cluster_stats();
        assert_eq!(cs[0].count(), 40.0);
        assert_eq!(cs[1].count(), 40.0);
    }

    #[test]
    fn step_stats_match_labels_exactly() {
        let data = two_blob_data();
        let (params, prior) = params_two_clusters();
        let plan = params.plan();
        let mut shard = Shard::new(0..80, Xoshiro256pp::seed_from_u64(3));
        let bundle = shard_step(&data, &mut shard, &plan, &prior);
        // Recompute stats from labels and compare.
        let mut expect = StatsBundle::empty(&prior, 2);
        for local in 0..80 {
            expect.sub_stats[shard.z[local] as usize][shard.zsub[local] as usize]
                .add(data.row(local));
        }
        for k in 0..2 {
            for h in 0..2 {
                assert_eq!(
                    bundle.sub_stats[k][h].count(),
                    expect.sub_stats[k][h].count(),
                    "k={k} h={h}"
                );
            }
        }
    }

    #[test]
    fn tiled_matches_scalar_oracle_on_blobs() {
        // Odd tile widths exercise remainder handling; labels and
        // sub-labels must be identical draw for draw.
        let data = two_blob_data();
        let (params, prior) = params_two_clusters();
        let plan = params.plan();
        for tile in [1, 7, 64, 128, 256] {
            let mut tiled = Shard::new(0..80, Xoshiro256pp::seed_from_u64(17));
            let mut scalar = Shard::new(0..80, Xoshiro256pp::seed_from_u64(17));
            shard_step_tiled(&data, &mut tiled, &plan, &prior, tile);
            shard_step_scalar(&data, &mut scalar, &plan, &prior);
            assert_eq!(tiled.z, scalar.z, "tile={tile}");
            assert_eq!(tiled.zsub, scalar.zsub, "tile={tile}");
        }
    }

    #[test]
    fn kernel_desc_matches_params_loglik() {
        let prior = NiwPrior::weak(3);
        let mut s = prior.empty_stats();
        for i in 0..20 {
            s.add(&[i as f64 * 0.1, 1.0 - i as f64 * 0.05, 0.5]);
        }
        let p = prior.mean_params(&s);
        let desc = KernelDesc::new(&crate::stats::Params::Gauss(p.clone()), 0.0);
        for x in [[0.0, 0.0, 0.0], [1.0, -1.0, 2.0], [0.5, 0.9, 0.4]] {
            let a = desc.loglik(&x);
            let b = p.log_likelihood(&x);
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn splits_move_right_subcluster() {
        let mut shard = Shard::new(0..6, Xoshiro256pp::seed_from_u64(0));
        shard.z = vec![0, 0, 0, 1, 1, 2];
        shard.zsub = vec![0, 1, 1, 0, 1, 0];
        shard_apply_splits(&mut shard, &[SplitOp { target: 0, new_index: 3 }]);
        assert_eq!(shard.z, vec![0, 3, 3, 1, 1, 2]);
    }

    #[test]
    fn multi_split_single_pass_matches_sequential() {
        // Two simultaneous splits resolved via the lookup table: labels
        // land exactly where per-op passes would put them.
        let mut shard = Shard::new(0..8, Xoshiro256pp::seed_from_u64(0));
        shard.z = vec![0, 1, 2, 0, 1, 2, 1, 0];
        shard.zsub = vec![1, 0, 1, 0, 1, 0, 1, 1];
        shard_apply_splits(
            &mut shard,
            &[SplitOp { target: 0, new_index: 3 }, SplitOp { target: 2, new_index: 4 }],
        );
        assert_eq!(shard.z, vec![3, 1, 4, 0, 1, 2, 1, 3]);
    }

    #[test]
    fn merges_set_provenance_sublabels() {
        let mut shard = Shard::new(0..5, Xoshiro256pp::seed_from_u64(0));
        shard.z = vec![0, 2, 1, 2, 0];
        shard.zsub = vec![1, 1, 0, 0, 1];
        shard_apply_merges(&mut shard, &[MergeOp { keep: 0, absorb: 2 }]);
        assert_eq!(shard.z, vec![0, 0, 1, 0, 0]);
        assert_eq!(shard.zsub, vec![0, 1, 0, 1, 0]);
    }

    #[test]
    fn disjoint_merges_apply_in_one_pass() {
        let mut shard = Shard::new(0..6, Xoshiro256pp::seed_from_u64(0));
        shard.z = vec![0, 1, 2, 3, 2, 0];
        shard.zsub = vec![1, 1, 1, 1, 0, 0];
        shard_apply_merges(
            &mut shard,
            &[MergeOp { keep: 0, absorb: 2 }, MergeOp { keep: 1, absorb: 3 }],
        );
        assert_eq!(shard.z, vec![0, 1, 0, 1, 0, 0]);
        assert_eq!(shard.zsub, vec![0, 0, 1, 1, 1, 0]);
    }

    #[test]
    fn remap_compacts_indices() {
        let mut shard = Shard::new(0..4, Xoshiro256pp::seed_from_u64(0));
        shard.z = vec![0, 2, 2, 3];
        shard_remap(&mut shard, &[Some(0), None, Some(1), Some(2)]);
        assert_eq!(shard.z, vec![0, 1, 1, 2]);
    }

    #[test]
    fn multinomial_step_works() {
        // Two topics with disjoint support.
        let prior = Prior::DirMult(crate::stats::DirMultPrior::symmetric(4, 0.5));
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut state = DpmmState::new(1.0, prior.clone(), 2, 8, &mut rng);
        let mut s0 = prior.empty_stats();
        s0.add(&[10.0, 10.0, 0.0, 0.0]);
        let mut s1 = prior.empty_stats();
        s1.add(&[0.0, 0.0, 10.0, 10.0]);
        state.clusters[0].stats = s0.clone();
        state.clusters[0].params = prior.mean_params(&s0);
        state.clusters[0].sub_params = [prior.mean_params(&s0), prior.mean_params(&s0)];
        state.clusters[0].weight = 0.5;
        state.clusters[1].stats = s1.clone();
        state.clusters[1].params = prior.mean_params(&s1);
        state.clusters[1].sub_params = [prior.mean_params(&s1), prior.mean_params(&s1)];
        state.clusters[1].weight = 0.5;
        let plan = StepParams::snapshot(&state).plan();
        let data = Data::new(
            4,
            4,
            vec![
                5.0, 4.0, 0.0, 0.0, // topic 0
                0.0, 1.0, 6.0, 3.0, // topic 1
                7.0, 2.0, 1.0, 0.0, // topic 0
                0.0, 0.0, 2.0, 8.0, // topic 1
            ],
        );
        let mut shard = Shard::new(0..4, Xoshiro256pp::seed_from_u64(6));
        shard_step(&data, &mut shard, &plan, &prior);
        assert_eq!(shard.z, vec![0, 1, 0, 1]);
    }
}
