//! The per-shard restricted-Gibbs kernel shared by the native and
//! distributed backends (workers run exactly this code on their chunk).
//!
//! For every point: sample z_i ∝ π_k f(x_i; θ_k) over instantiated clusters
//! (Eq. 18), then z̄_i over the assigned cluster's two sub-clusters (Eq. 19),
//! and accumulate sufficient statistics into the sub-cluster accumulators
//! (cluster statistics are recovered as the sum of the two sub-clusters,
//! halving the accumulation work — the dominant O(N·d²) term for Gaussians).

use super::StatsBundle;
use crate::datagen::Data;
use crate::model::{LEFT, RIGHT};
use crate::rng::{Rng, Xoshiro256pp};
use crate::sampler::{MergeOp, SplitOp, StepParams};
use crate::stats::{Params, Prior};
use std::ops::Range;

/// One contiguous chunk of the dataset with its labels and private RNG.
#[derive(Debug, Clone)]
pub struct Shard {
    pub range: Range<usize>,
    /// Cluster label per point (index into the coordinator's cluster list).
    pub z: Vec<u32>,
    /// Sub-cluster label per point (LEFT/RIGHT).
    pub zsub: Vec<u8>,
    pub rng: Xoshiro256pp,
}

impl Shard {
    pub fn new(range: Range<usize>, rng: Xoshiro256pp) -> Self {
        let n = range.len();
        Self { range, z: vec![0; n], zsub: vec![0; n], rng }
    }

    pub fn len(&self) -> usize {
        self.range.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Scratch buffers reused across points (avoids per-point allocation in the
/// hot loop; see EXPERIMENTS.md §Perf).
pub struct ShardScratch {
    loglik: Vec<f64>,
    diff: Vec<f64>,
}

impl ShardScratch {
    pub fn new(k_max: usize, d: usize) -> Self {
        Self { loglik: vec![0.0; k_max.max(2)], diff: vec![0.0; d] }
    }
}

/// Gaussian log-likelihood with caller-provided scratch: c − ½‖L⁻¹(x−μ)‖².
/// Uses the cached inverse-Cholesky rows directly (no triangular solve),
/// mirroring the matmul form the Pallas kernel uses.
#[inline]
fn gauss_loglik(p: &crate::stats::NiwParams, x: &[f64], scratch: &mut ShardScratch) -> f64 {
    let d = x.len();
    let diff = &mut scratch.diff[..d];
    for (dv, (&xv, &mv)) in diff.iter_mut().zip(x.iter().zip(&p.mu)) {
        *dv = xv - mv;
    }
    // y = W diff with W = L⁻¹ lower-triangular; maha = ‖y‖². Flat slice
    // walk + iterator zips keep the inner loop free of bounds checks.
    let w = p.inv_chol.data();
    let mut maha = 0.0;
    let mut off = 0;
    for i in 0..d {
        let mut acc = 0.0;
        for (&wv, &dv) in w[off..off + i + 1].iter().zip(diff.iter()) {
            acc += wv * dv;
        }
        maha += acc * acc;
        off += d;
    }
    p.log_norm - 0.5 * maha
}

#[inline]
fn loglik(params: &Params, x: &[f64], scratch: &mut ShardScratch) -> f64 {
    match params {
        Params::Gauss(p) => gauss_loglik(p, x, scratch),
        Params::Mult(p) => p.log_likelihood(x),
    }
}

/// Run steps (e)/(f) + statistics on one shard. Labels are written in place;
/// the returned bundle holds this shard's contribution.
pub fn shard_step(
    data: &Data,
    shard: &mut Shard,
    params: &StepParams,
    prior: &Prior,
) -> StatsBundle {
    let k = params.k();
    let mut bundle = StatsBundle::empty(prior, k);
    let mut scratch = ShardScratch::new(k, data.d);
    for (local, i) in shard.range.clone().enumerate() {
        let x = data.row(i);
        // Step (e): z_i ∝ π_k f(x; θ_k) — categorical draw via a stable
        // exp-scan (one RNG draw + K exps; the equivalent Gumbel-argmax
        // costs K draws + 2K logs and dominated the profile, see
        // EXPERIMENTS.md §Perf).
        let mut best = f64::NEG_INFINITY;
        for c in 0..k {
            let lw = params.log_weights[c] + loglik(&params.params[c], x, &mut scratch);
            scratch.loglik[c] = lw;
            if lw > best {
                best = lw;
            }
        }
        let mut total = 0.0;
        for c in 0..k {
            let gap = scratch.loglik[c] - best;
            // exp(−36) ≈ 2e-16: below one ULP of the running sum, so the
            // cluster can't be drawn — skip the transcendental.
            let e = if gap < -36.0 { 0.0 } else { gap.exp() };
            scratch.loglik[c] = e;
            total += e;
        }
        let mut t = shard.rng.next_f64() * total;
        let mut zi = k - 1;
        for (c, &e) in scratch.loglik[..k].iter().enumerate() {
            t -= e;
            if t < 0.0 {
                zi = c;
                break;
            }
        }
        // Step (f): z̄_i over the assigned cluster's sub-clusters — a
        // two-way categorical from the log-odds.
        let sub_lw_l = params.sub_log_weights[zi][LEFT]
            + loglik(&params.sub_params[zi][LEFT], x, &mut scratch);
        let sub_lw_r = params.sub_log_weights[zi][RIGHT]
            + loglik(&params.sub_params[zi][RIGHT], x, &mut scratch);
        // P(right) = 1 / (1 + exp(lw_l − lw_r))
        let p_right = 1.0 / (1.0 + (sub_lw_l - sub_lw_r).exp());
        let hi = usize::from(shard.rng.next_f64() < p_right);
        shard.z[local] = zi as u32;
        shard.zsub[local] = hi as u8;
        bundle.sub_stats[zi][hi].add(x);
    }
    bundle
}

/// Apply accepted splits to a shard's labels (mirrors
/// [`crate::sampler::apply_split`]'s state change).
pub fn shard_apply_splits(shard: &mut Shard, ops: &[SplitOp]) {
    for op in ops {
        for local in 0..shard.len() {
            if shard.z[local] as usize == op.target {
                if shard.zsub[local] as usize == RIGHT {
                    shard.z[local] = op.new_index as u32;
                }
                // Fresh sub-assignment for the next sweep (children start
                // with random sub-clusters, like the reference impl).
                shard.zsub[local] = (shard.rng.next_u64() & 1) as u8;
            }
        }
    }
}

/// Apply accepted merges to a shard's labels.
pub fn shard_apply_merges(shard: &mut Shard, ops: &[MergeOp]) {
    for op in ops {
        for local in 0..shard.len() {
            let zi = shard.z[local] as usize;
            if zi == op.keep {
                shard.zsub[local] = LEFT as u8;
            } else if zi == op.absorb {
                shard.z[local] = op.keep as u32;
                shard.zsub[local] = RIGHT as u8;
            }
        }
    }
}

/// Apply a removal remap to a shard's labels.
pub fn shard_remap(shard: &mut Shard, map: &[Option<usize>]) {
    for local in 0..shard.len() {
        let old = shard.z[local] as usize;
        match map.get(old).copied().flatten() {
            Some(new) => shard.z[local] = new as u32,
            None => {
                // Point's cluster vanished (should only happen for empty
                // clusters — impossible — or after external surgery).
                // Reassign to cluster 0 defensively.
                shard.z[local] = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DpmmState;
    use crate::stats::NiwPrior;

    fn two_blob_data() -> Data {
        // 40 points at (−10, 0), 40 at (10, 0) with tiny deterministic jitter.
        let mut values = Vec::new();
        for i in 0..40 {
            values.push(-10.0 + 0.01 * i as f64);
            values.push(0.0);
        }
        for i in 0..40 {
            values.push(10.0 + 0.01 * i as f64);
            values.push(0.0);
        }
        Data::new(80, 2, values)
    }

    fn params_two_clusters() -> (StepParams, Prior) {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut state = DpmmState::new(1.0, prior.clone(), 2, 80, &mut rng);
        // Hand-place the clusters on the blobs.
        for (k, center) in [(-10.0f64, 0), (10.0, 1)].map(|(c, k)| (k, c)) {
            let mut s = prior.empty_stats();
            for j in 0..50 {
                s.add(&[center + 0.01 * j as f64, 0.0]);
            }
            state.clusters[k].stats = s.clone();
            state.clusters[k].sub_stats = [s.clone(), s];
            state.clusters[k].params = prior.mean_params(&state.clusters[k].stats);
            state.clusters[k].sub_params = [
                prior.mean_params(&state.clusters[k].sub_stats[0]),
                prior.mean_params(&state.clusters[k].sub_stats[1]),
            ];
            state.clusters[k].weight = 0.5;
        }
        (StepParams::snapshot(&state), prior)
    }

    #[test]
    fn step_assigns_points_to_nearest_cluster() {
        let data = two_blob_data();
        let (params, prior) = params_two_clusters();
        let mut shard = Shard::new(0..80, Xoshiro256pp::seed_from_u64(9));
        let bundle = shard_step(&data, &mut shard, &params, &prior);
        for local in 0..40 {
            assert_eq!(shard.z[local], 0, "left blob must go to cluster 0");
        }
        for local in 40..80 {
            assert_eq!(shard.z[local], 1);
        }
        let cs = bundle.cluster_stats();
        assert_eq!(cs[0].count(), 40.0);
        assert_eq!(cs[1].count(), 40.0);
    }

    #[test]
    fn step_stats_match_labels_exactly() {
        let data = two_blob_data();
        let (params, prior) = params_two_clusters();
        let mut shard = Shard::new(0..80, Xoshiro256pp::seed_from_u64(3));
        let bundle = shard_step(&data, &mut shard, &params, &prior);
        // Recompute stats from labels and compare.
        let mut expect = StatsBundle::empty(&prior, 2);
        for local in 0..80 {
            expect.sub_stats[shard.z[local] as usize][shard.zsub[local] as usize]
                .add(data.row(local));
        }
        for k in 0..2 {
            for h in 0..2 {
                assert_eq!(
                    bundle.sub_stats[k][h].count(),
                    expect.sub_stats[k][h].count(),
                    "k={k} h={h}"
                );
            }
        }
    }

    #[test]
    fn gauss_loglik_matches_params_method() {
        let prior = NiwPrior::weak(3);
        let mut s = prior.empty_stats();
        for i in 0..20 {
            s.add(&[i as f64 * 0.1, 1.0 - i as f64 * 0.05, 0.5]);
        }
        let p = prior.mean_params(&s);
        let mut scratch = ShardScratch::new(4, 3);
        for x in [[0.0, 0.0, 0.0], [1.0, -1.0, 2.0], [0.5, 0.9, 0.4]] {
            let a = gauss_loglik(&p, &x, &mut scratch);
            let b = p.log_likelihood(&x);
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
    }

    #[test]
    fn splits_move_right_subcluster() {
        let mut shard = Shard::new(0..6, Xoshiro256pp::seed_from_u64(0));
        shard.z = vec![0, 0, 0, 1, 1, 2];
        shard.zsub = vec![0, 1, 1, 0, 1, 0];
        shard_apply_splits(&mut shard, &[SplitOp { target: 0, new_index: 3 }]);
        assert_eq!(shard.z, vec![0, 3, 3, 1, 1, 2]);
    }

    #[test]
    fn merges_set_provenance_sublabels() {
        let mut shard = Shard::new(0..5, Xoshiro256pp::seed_from_u64(0));
        shard.z = vec![0, 2, 1, 2, 0];
        shard.zsub = vec![1, 1, 0, 0, 1];
        shard_apply_merges(&mut shard, &[MergeOp { keep: 0, absorb: 2 }]);
        assert_eq!(shard.z, vec![0, 0, 1, 0, 0]);
        assert_eq!(shard.zsub, vec![0, 1, 0, 1, 0]);
    }

    #[test]
    fn remap_compacts_indices() {
        let mut shard = Shard::new(0..4, Xoshiro256pp::seed_from_u64(0));
        shard.z = vec![0, 2, 2, 3];
        shard_remap(&mut shard, &[Some(0), None, Some(1), Some(2)]);
        assert_eq!(shard.z, vec![0, 1, 1, 2]);
    }

    #[test]
    fn multinomial_step_works() {
        // Two topics with disjoint support.
        let prior = Prior::DirMult(crate::stats::DirMultPrior::symmetric(4, 0.5));
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut state = DpmmState::new(1.0, prior.clone(), 2, 8, &mut rng);
        let mut s0 = prior.empty_stats();
        s0.add(&[10.0, 10.0, 0.0, 0.0]);
        let mut s1 = prior.empty_stats();
        s1.add(&[0.0, 0.0, 10.0, 10.0]);
        state.clusters[0].stats = s0.clone();
        state.clusters[0].params = prior.mean_params(&s0);
        state.clusters[0].sub_params = [prior.mean_params(&s0), prior.mean_params(&s0)];
        state.clusters[0].weight = 0.5;
        state.clusters[1].stats = s1.clone();
        state.clusters[1].params = prior.mean_params(&s1);
        state.clusters[1].sub_params = [prior.mean_params(&s1), prior.mean_params(&s1)];
        state.clusters[1].weight = 0.5;
        let params = StepParams::snapshot(&state);
        let data = Data::new(
            4,
            4,
            vec![
                5.0, 4.0, 0.0, 0.0, // topic 0
                0.0, 1.0, 6.0, 3.0, // topic 1
                7.0, 2.0, 1.0, 0.0, // topic 0
                0.0, 0.0, 2.0, 8.0, // topic 1
            ],
        );
        let mut shard = Shard::new(0..4, Xoshiro256pp::seed_from_u64(6));
        shard_step(&data, &mut shard, &params, &prior);
        assert_eq!(shard.z, vec![0, 1, 0, 1]);
    }
}
