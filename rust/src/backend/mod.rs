//! Execution backends: where steps (e)/(f) — label sampling — and the
//! sufficient-statistics pass actually run.
//!
//! The coordinator is backend-agnostic; a [`Backend`] owns the data shards
//! and per-point labels and exposes exactly four operations per iteration:
//! `step` (sample labels, return aggregated sufficient statistics),
//! `apply_splits`, `apply_merges`, and `remap`. The coordinator↔backend
//! interface carries only parameters and statistics, never data — the
//! paper's key distribution property.
//!
//! * [`native`] — multi-core CPU threads (the paper's Julia package analog).
//! * [`xla`] — AOT-compiled JAX/Pallas shard-step artifacts via PJRT (the
//!   paper's CUDA/C++ package analog).
//! * [`distributed`] — TCP leader/worker processes (the paper's
//!   multi-machine Julia mode analog).
//!
//! Within a backend, the per-shard sweep itself runs through the
//! [`executor`] seam: a [`crate::sampler::ScoreGraph`] kernel IR describes
//! the sweep, and an [`executor::Executor`] (scalar oracle, tiled/SIMD,
//! or multi-stream device emulation) executes it — all bound by the
//! bitwise conformance suite in `tests/prop_kernel_equiv.rs`.

pub mod distributed;
pub mod executor;
pub mod native;
pub mod shard;
pub mod xla;

use crate::sampler::{MergeOp, SplitOp, StepParams};
use crate::stats::Stats;
use anyhow::Result;

/// Sufficient statistics aggregated over all shards, aligned with the
/// coordinator's cluster list: `sub_stats[k] = [C̄_kl, C̄_kr]` and the cluster
/// statistics are their sum (a cluster is the disjoint union of its
/// sub-clusters).
#[derive(Debug, Clone)]
pub struct StatsBundle {
    pub sub_stats: Vec<[Stats; 2]>,
}

impl StatsBundle {
    /// Cluster-level statistics: C_k = C̄_kl ∪ C̄_kr.
    pub fn cluster_stats(&self) -> Vec<Stats> {
        self.sub_stats
            .iter()
            .map(|[l, r]| {
                let mut s = l.clone();
                s.merge(r);
                s
            })
            .collect()
    }

    /// Element-wise merge (reduction across shards / workers).
    pub fn merge(&mut self, other: &StatsBundle) {
        assert_eq!(self.sub_stats.len(), other.sub_stats.len());
        for (a, b) in self.sub_stats.iter_mut().zip(&other.sub_stats) {
            a[0].merge(&b[0]);
            a[1].merge(&b[1]);
        }
    }

    pub fn empty(prior: &crate::stats::Prior, k: usize) -> Self {
        StatsBundle {
            sub_stats: (0..k).map(|_| [prior.empty_stats(), prior.empty_stats()]).collect(),
        }
    }
}

/// A label-sampling + statistics execution engine over sharded data.
pub trait Backend {
    /// Human-readable backend name (for logs/results).
    fn name(&self) -> &'static str;

    /// Run one restricted-Gibbs label pass (steps (e)/(f)) under `params`
    /// and return freshly aggregated sufficient statistics.
    fn step(&mut self, params: &StepParams) -> Result<StatsBundle>;

    /// Rewrite labels for accepted splits (applied in order): points of
    /// `op.target` move to `op.target`/`op.new_index` according to their
    /// sub-label; sub-labels of moved points are re-randomized.
    fn apply_splits(&mut self, ops: &[SplitOp]) -> Result<()>;

    /// Rewrite labels for accepted merges: `absorb`'s points join `keep`,
    /// sub-labels record the provenance (keep → left, absorb → right).
    fn apply_merges(&mut self, ops: &[MergeOp]) -> Result<()>;

    /// Apply a cluster-index remap after removals (`map[old] = Some(new)`).
    fn remap(&mut self, map: &[Option<usize>]) -> Result<()>;

    /// Gather the full label vector (order = original data order). Only
    /// called at the end of a fit / for diagnostics — O(N) traffic.
    fn labels(&self) -> Result<Vec<usize>>;

    /// Restore a full label vector (checkpoint resume). Sub-labels are
    /// re-randomized; they are resampled before first use anyway.
    /// Backends that cannot restore labels return an error.
    fn set_labels(&mut self, _labels: &[u32]) -> Result<()> {
        anyhow::bail!("backend '{}' does not support label restore", self.name())
    }

    /// Total number of points.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{NiwPrior, Prior};

    #[test]
    fn bundle_cluster_stats_sum_subclusters() {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut b = StatsBundle::empty(&prior, 2);
        b.sub_stats[0][0].add(&[1.0, 0.0]);
        b.sub_stats[0][1].add(&[3.0, 0.0]);
        b.sub_stats[1][0].add(&[5.0, 5.0]);
        let cs = b.cluster_stats();
        assert_eq!(cs[0].count(), 2.0);
        assert_eq!(cs[1].count(), 1.0);
    }

    #[test]
    fn bundle_merge_adds() {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut a = StatsBundle::empty(&prior, 1);
        let mut b = StatsBundle::empty(&prior, 1);
        a.sub_stats[0][0].add(&[1.0, 1.0]);
        b.sub_stats[0][0].add(&[2.0, 2.0]);
        b.sub_stats[0][1].add(&[0.0, 1.0]);
        a.merge(&b);
        assert_eq!(a.sub_stats[0][0].count(), 2.0);
        assert_eq!(a.sub_stats[0][1].count(), 1.0);
    }
}
