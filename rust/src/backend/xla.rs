//! XLA/PJRT backend (the paper's CUDA/C++ GPU package analog).
//!
//! Each iteration executes one AOT-compiled shard-step artifact per shard:
//! the L1 Pallas log-likelihood kernel + L2 label sampling + the O(n·K)
//! statistics, all fused in one XLA program. The Rust side
//!
//! * keeps the f32 shard tensors prepared once up front (the analog of the
//!   paper's device-resident `d_points`),
//! * generates the Gumbel noise that makes the pure program a sampler,
//! * converts the returned counts/Σx to f64 statistics and accumulates the
//!   O(n·d²) Gaussian scatter matrices host-side from the returned labels
//!   (see python/compile/model.py for why that split is TPU-idiomatic),
//! * mirrors the paper's §4.2 run-time kernel selection: the `direct` or
//!   `matmul` Pallas variant is chosen by the d×n product (configurable
//!   crossover, calibrated by the `table_kernel_crossover` bench).

use super::shard::{shard_apply_merges, shard_apply_splits, shard_remap, Shard};
use super::{Backend, StatsBundle};
use crate::datagen::Data;
use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::{ArtifactEntry, HostTensor, XlaRuntime};
use crate::sampler::{MergeOp, SplitOp, StepParams};
use crate::stats::{Params, Prior, Stats};
use anyhow::{anyhow, bail, Context, Result};
use std::sync::Arc;

/// Kernel-variant selection policy (§4.2 of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelChoice {
    /// Pick by d×n: `direct` below the crossover, `matmul` above.
    Auto { crossover: usize },
    Direct,
    Matmul,
}

impl Default for KernelChoice {
    fn default() -> Self {
        // The paper measured 640k on a Quadro RTX 4000; our CPU-PJRT
        // calibration (table_kernel_crossover bench) lands in the same
        // order of magnitude.
        KernelChoice::Auto { crossover: 640_000 }
    }
}

impl KernelChoice {
    fn pick(&self, d: usize, n: usize) -> &'static str {
        match self {
            KernelChoice::Direct => "direct",
            KernelChoice::Matmul => "matmul",
            KernelChoice::Auto { crossover } => {
                if d * n < *crossover {
                    "direct"
                } else {
                    "matmul"
                }
            }
        }
    }
}

/// Configuration for [`XlaBackend`].
#[derive(Debug, Clone)]
pub struct XlaConfig {
    /// Artifact directory (with manifest.json).
    pub artifact_dir: std::path::PathBuf,
    /// Preferred shard size; the smallest artifact with n ≥ this is used.
    pub shard_size: usize,
    pub kernel: KernelChoice,
}

impl Default for XlaConfig {
    fn default() -> Self {
        Self {
            artifact_dir: std::path::PathBuf::from("artifacts"),
            shard_size: 4096,
            kernel: KernelChoice::default(),
        }
    }
}

/// AOT-artifact execution backend.
pub struct XlaBackend {
    runtime: XlaRuntime,
    entry: ArtifactEntry,
    data: Arc<Data>,
    prior: Prior,
    likelihood: &'static str,
    shards: Vec<Shard>,
    /// Pre-packed f32 tensors per shard: x (n_art × d) and mask (n_art).
    shard_x: Vec<Vec<f32>>,
    shard_mask: Vec<Vec<f32>>,
}

impl XlaBackend {
    pub fn new(data: Arc<Data>, prior: Prior, config: XlaConfig, rng: &mut impl Rng) -> Result<Self> {
        let likelihood = match &prior {
            Prior::Niw(_) => "gaussian",
            Prior::DirMult(_) => "multinomial",
        };
        let runtime = XlaRuntime::new(&config.artifact_dir)?;
        let d = data.d;
        let want_n = config.shard_size.min(data.n.next_power_of_two());
        let kernel = match likelihood {
            "multinomial" => "matmul",
            _ => config.kernel.pick(d, want_n),
        };
        let entry = runtime
            .manifest()
            .select(likelihood, kernel, d, 2, want_n.min(config.shard_size))
            .or_else(|| runtime.manifest().select(likelihood, kernel, d, 2, 1))
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for likelihood={likelihood} kernel={kernel} d={d}; \
                     available shapes: {:?} — extend python/compile/aot.py's manifest",
                    runtime.manifest().shapes(likelihood, kernel)
                )
            })?;
        let n_art = entry.n;
        let mut shards = Vec::new();
        let mut shard_x = Vec::new();
        let mut shard_mask = Vec::new();
        for range in data.shard_ranges(n_art) {
            let mut shard = Shard::new(range.clone(), rng.fork());
            for s in shard.zsub.iter_mut() {
                *s = (shard.rng.next_u64() & 1) as u8;
            }
            let mut x = vec![0.0f32; n_art * d];
            let mut mask = vec![0.0f32; n_art];
            for (local, i) in range.clone().enumerate() {
                for (slot, &v) in x[local * d..(local + 1) * d].iter_mut().zip(data.row(i)) {
                    *slot = v as f32;
                }
                mask[local] = 1.0;
            }
            shards.push(shard);
            shard_x.push(x);
            shard_mask.push(mask);
        }
        Ok(Self { runtime, entry, data, prior, likelihood, shards, shard_x, shard_mask })
    }

    /// The selected artifact (kernel variant, shapes) — exposed for logs and
    /// the crossover bench.
    pub fn artifact(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Scatter initial labels uniformly over `k` clusters.
    pub fn randomize_labels(&mut self, k: usize) {
        for shard in &mut self.shards {
            for local in 0..shard.len() {
                shard.z[local] = shard.rng.next_range(k) as u32;
                shard.zsub[local] = (shard.rng.next_u64() & 1) as u8;
            }
        }
    }

    /// Pack per-cluster parameter tensors, padding dead slots to the
    /// artifact's static K.
    fn pack_params(&self, params: &StepParams) -> Result<Vec<HostTensor>> {
        let (d, k_art) = (self.entry.d, self.entry.k);
        let k_live = params.k();
        if k_live > k_art {
            bail!(
                "live clusters ({k_live}) exceed artifact K ({k_art}); raise max_clusters \
                 artifact shapes in python/compile/aot.py"
            );
        }
        const DEAD: f32 = -1.0e30;
        match self.likelihood {
            "gaussian" => {
                let mut logw = vec![DEAD; k_art];
                let mut mu = vec![0.0f32; k_art * d];
                let mut w = vec![0.0f32; k_art * d * d];
                let mut c = vec![0.0f32; k_art];
                let mut sub_logw = vec![DEAD; k_art * 2];
                let mut sub_mu = vec![0.0f32; k_art * 2 * d];
                let mut sub_w = vec![0.0f32; k_art * 2 * d * d];
                let mut sub_c = vec![0.0f32; k_art * 2];
                // Identity W for dead slots keeps the kernel numerically tame.
                for slot in 0..k_art {
                    for j in 0..d {
                        w[slot * d * d + j * d + j] = 1.0;
                        sub_w[(slot * 2) * d * d + j * d + j] = 1.0;
                        sub_w[(slot * 2 + 1) * d * d + j * d + j] = 1.0;
                    }
                }
                for (kk, p) in params.params.iter().enumerate() {
                    let g = match p {
                        Params::Gauss(g) => g,
                        _ => bail!("gaussian backend got non-gaussian params"),
                    };
                    logw[kk] = params.log_weights[kk] as f32;
                    c[kk] = g.log_norm as f32;
                    for j in 0..d {
                        mu[kk * d + j] = g.mu[j] as f32;
                    }
                    for (slot, &v) in
                        w[kk * d * d..(kk + 1) * d * d].iter_mut().zip(g.inv_chol.data())
                    {
                        *slot = v as f32;
                    }
                    for h in 0..2 {
                        let sg = match &params.sub_params[kk][h] {
                            Params::Gauss(g) => g,
                            _ => bail!("gaussian backend got non-gaussian sub-params"),
                        };
                        let flat = kk * 2 + h;
                        sub_logw[flat] = params.sub_log_weights[kk][h] as f32;
                        sub_c[flat] = sg.log_norm as f32;
                        for j in 0..d {
                            sub_mu[flat * d + j] = sg.mu[j] as f32;
                        }
                        for (slot, &v) in sub_w[flat * d * d..(flat + 1) * d * d]
                            .iter_mut()
                            .zip(sg.inv_chol.data())
                        {
                            *slot = v as f32;
                        }
                    }
                }
                Ok(vec![
                    HostTensor::f32(logw, &[k_art]),
                    HostTensor::f32(mu, &[k_art, d]),
                    HostTensor::f32(w, &[k_art, d, d]),
                    HostTensor::f32(c, &[k_art]),
                    HostTensor::f32(sub_logw, &[k_art, 2]),
                    HostTensor::f32(sub_mu, &[k_art, 2, d]),
                    HostTensor::f32(sub_w, &[k_art, 2, d, d]),
                    HostTensor::f32(sub_c, &[k_art, 2]),
                ])
            }
            "multinomial" => {
                let mut logw = vec![DEAD; k_art];
                let mut log_theta = vec![(1e-30f32).ln(); k_art * d];
                let mut sub_logw = vec![DEAD; k_art * 2];
                let mut sub_log_theta = vec![(1e-30f32).ln(); k_art * 2 * d];
                for (kk, p) in params.params.iter().enumerate() {
                    let m = match p {
                        Params::Mult(m) => m,
                        _ => bail!("multinomial backend got non-multinomial params"),
                    };
                    logw[kk] = params.log_weights[kk] as f32;
                    for j in 0..d {
                        log_theta[kk * d + j] = m.log_theta[j] as f32;
                    }
                    for h in 0..2 {
                        let sm = match &params.sub_params[kk][h] {
                            Params::Mult(m) => m,
                            _ => bail!("multinomial backend got non-multinomial sub-params"),
                        };
                        let flat = kk * 2 + h;
                        sub_logw[flat] = params.sub_log_weights[kk][h] as f32;
                        for j in 0..d {
                            sub_log_theta[flat * d + j] = sm.log_theta[j] as f32;
                        }
                    }
                }
                Ok(vec![
                    HostTensor::f32(logw, &[k_art]),
                    HostTensor::f32(log_theta, &[k_art, d]),
                    HostTensor::f32(sub_logw, &[k_art, 2]),
                    HostTensor::f32(sub_log_theta, &[k_art, 2, d]),
                ])
            }
            other => bail!("unknown likelihood {other}"),
        }
    }

    fn gumbel_tensor(rng: &mut Xoshiro256pp, rows: usize, cols: usize) -> HostTensor {
        let mut g = vec![0.0f32; rows * cols];
        for v in g.iter_mut() {
            let u = rng.next_f64_open();
            *v = (-(-u.ln()).ln()) as f32;
        }
        HostTensor::f32(g, &[rows, cols])
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn step(&mut self, params: &StepParams) -> Result<StatsBundle> {
        let k_live = params.k();
        let (n_art, d, k_art) = (self.entry.n, self.entry.d, self.entry.k);
        let param_tensors = self.pack_params(params)?;
        let mut bundle = StatsBundle::empty(&self.prior, k_live);
        for s in 0..self.shards.len() {
            let gumbel = Self::gumbel_tensor(&mut self.shards[s].rng, n_art, k_art);
            let gumbel_sub = Self::gumbel_tensor(&mut self.shards[s].rng, n_art, 2);
            let mut inputs: Vec<HostTensor> = Vec::with_capacity(param_tensors.len() + 4);
            inputs.push(HostTensor::f32(self.shard_x[s].clone(), &[n_art, d]));
            inputs.push(HostTensor::f32(self.shard_mask[s].clone(), &[n_art]));
            inputs.extend(param_tensors.iter().cloned());
            inputs.push(gumbel);
            inputs.push(gumbel_sub);
            let out = self
                .runtime
                .execute(&self.entry.name, &inputs)
                .with_context(|| format!("executing {} on shard {s}", self.entry.name))?;
            if out.len() != 4 {
                bail!("artifact returned {} outputs, expected 4", out.len());
            }
            let z = out[0].as_i32()?;
            let zsub = out[1].as_i32()?;
            let counts = out[2].as_f32()?; // (k_art, 2)
            let sumx = out[3].as_f32()?; // (k_art, 2, d)
            // Record labels (valid rows only).
            let shard = &mut self.shards[s];
            for local in 0..shard.len() {
                shard.z[local] = z[local].clamp(0, k_live.max(1) as i32 - 1) as u32;
                shard.zsub[local] = (zsub[local] & 1) as u8;
            }
            // Fold device statistics into the f64 bundle.
            match &self.prior {
                Prior::DirMult(_) => {
                    for kk in 0..k_live {
                        for h in 0..2 {
                            let flat = kk * 2 + h;
                            if let Stats::Mult(ms) = &mut bundle.sub_stats[kk][h] {
                                ms.n += counts[flat] as f64;
                                for j in 0..d {
                                    ms.sum_x[j] += sumx[flat * d + j] as f64;
                                }
                            }
                        }
                    }
                }
                Prior::Niw(_) => {
                    // counts + Σx from device; Σxxᵀ accumulated host-side
                    // from the labels (O(n·d²), threads not needed at
                    // artifact shard sizes).
                    for kk in 0..k_live {
                        for h in 0..2 {
                            let flat = kk * 2 + h;
                            if let Stats::Gauss(gs) = &mut bundle.sub_stats[kk][h] {
                                gs.n += counts[flat] as f64;
                                for j in 0..d {
                                    gs.sum_x[j] += sumx[flat * d + j] as f64;
                                }
                            }
                        }
                    }
                    let shard = &self.shards[s];
                    for (local, i) in shard.range.clone().enumerate() {
                        let kk = shard.z[local] as usize;
                        let h = shard.zsub[local] as usize;
                        if let Stats::Gauss(gs) = &mut bundle.sub_stats[kk][h] {
                            gs.sum_xxt.add_outer(self.data.row(i), 1.0);
                        }
                    }
                }
            }
        }
        Ok(bundle)
    }

    fn apply_splits(&mut self, ops: &[SplitOp]) -> Result<()> {
        for shard in &mut self.shards {
            shard_apply_splits(shard, ops);
        }
        Ok(())
    }

    fn apply_merges(&mut self, ops: &[MergeOp]) -> Result<()> {
        for shard in &mut self.shards {
            shard_apply_merges(shard, ops);
        }
        Ok(())
    }

    fn remap(&mut self, map: &[Option<usize>]) -> Result<()> {
        for shard in &mut self.shards {
            shard_remap(shard, map);
        }
        Ok(())
    }

    fn labels(&self) -> Result<Vec<usize>> {
        let mut out = vec![0usize; self.data.n];
        for shard in &self.shards {
            for (local, i) in shard.range.clone().enumerate() {
                out[i] = shard.z[local] as usize;
            }
        }
        Ok(out)
    }

    fn set_labels(&mut self, labels: &[u32]) -> Result<()> {
        anyhow::ensure!(labels.len() == self.data.n, "label count mismatch");
        for shard in &mut self.shards {
            for (local, i) in shard.range.clone().enumerate() {
                shard.z[local] = labels[i];
                shard.zsub[local] = (shard.rng.next_u64() & 1) as u8;
            }
        }
        Ok(())
    }

    fn len(&self) -> usize {
        self.data.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DpmmState;
    use crate::stats::NiwPrior;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifact_dir().join("manifest.json").exists()
    }

    fn blob_data(centers: &[[f64; 2]], per: usize) -> Arc<Data> {
        let mut values = Vec::new();
        for (ci, c) in centers.iter().enumerate() {
            for i in 0..per {
                values.push(c[0] + 0.01 * ((i + ci) % 7) as f64);
                values.push(c[1] - 0.01 * ((i * 3 + ci) % 5) as f64);
            }
        }
        Arc::new(Data::new(centers.len() * per, 2, values))
    }

    fn state_on(centers: &[[f64; 2]], per: usize) -> DpmmState {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let mut state =
            DpmmState::new(1.0, prior.clone(), centers.len(), centers.len() * per, &mut rng);
        for (k, c) in centers.iter().enumerate() {
            let mut s = prior.empty_stats();
            for i in 0..per {
                s.add(&[c[0] + 0.01 * i as f64, c[1]]);
            }
            state.clusters[k].stats = s.clone();
            state.clusters[k].sub_stats = [s.clone(), s.clone()];
            state.clusters[k].params = prior.mean_params(&s);
            state.clusters[k].sub_params = [prior.mean_params(&s), prior.mean_params(&s)];
            state.clusters[k].weight = 1.0 / centers.len() as f64;
        }
        state
    }

    #[test]
    fn xla_step_recovers_separated_blobs() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let centers = [[-20.0, 0.0], [20.0, 0.0]];
        let data = blob_data(&centers, 100);
        let state = state_on(&centers, 100);
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let config = XlaConfig { artifact_dir: artifact_dir(), shard_size: 256, ..Default::default() };
        let mut backend = XlaBackend::new(Arc::clone(&data), state.prior.clone(), config, &mut rng).unwrap();
        let bundle = backend.step(&StepParams::snapshot(&state)).unwrap();
        let cs = bundle.cluster_stats();
        assert_eq!(cs.len(), 2);
        assert_eq!(cs[0].count(), 100.0);
        assert_eq!(cs[1].count(), 100.0);
        let labels = backend.labels().unwrap();
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(l, i / 100, "point {i}");
        }
        // Gaussian scatter accumulated host-side must match a recount.
        if let Stats::Gauss(gs) = &cs[0] {
            assert!(gs.sum_xxt[(0, 0)] > 0.0);
            assert!((gs.sum_x[0] / gs.n - (-20.0)).abs() < 0.1);
        } else {
            panic!("expected gaussian stats");
        }
    }

    #[test]
    fn xla_stats_agree_with_native() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        use crate::backend::native::{NativeBackend, NativeConfig};
        let centers = [[-20.0, 0.0], [0.0, 20.0], [20.0, 0.0]];
        let data = blob_data(&centers, 80);
        let state = state_on(&centers, 80);
        let params = StepParams::snapshot(&state);
        let mut rng1 = Xoshiro256pp::seed_from_u64(1);
        let mut nb = NativeBackend::new(
            Arc::clone(&data),
            state.prior.clone(),
            NativeConfig { shard_size: 64, threads: 2, ..NativeConfig::default() },
            &mut rng1,
        );
        let native_bundle = nb.step(&params).unwrap();
        let mut rng2 = Xoshiro256pp::seed_from_u64(2);
        let config = XlaConfig { artifact_dir: artifact_dir(), shard_size: 256, ..Default::default() };
        let mut xb = XlaBackend::new(Arc::clone(&data), state.prior.clone(), config, &mut rng2).unwrap();
        let xla_bundle = xb.step(&params).unwrap();
        // Different RNG streams, but on well-separated data the cluster
        // assignments are deterministic → identical cluster-level stats.
        let ncs = native_bundle.cluster_stats();
        let xcs = xla_bundle.cluster_stats();
        for k in 0..3 {
            assert_eq!(ncs[k].count(), xcs[k].count(), "cluster {k}");
            if let (Stats::Gauss(a), Stats::Gauss(b)) = (&ncs[k], &xcs[k]) {
                for j in 0..2 {
                    assert!((a.sum_x[j] - b.sum_x[j]).abs() < 0.05, "sum_x k={k} j={j}");
                }
                assert!(a.sum_xxt.frob_dist(&b.sum_xxt) < 1.0);
            }
        }
    }

    #[test]
    fn kernel_choice_policies() {
        assert_eq!(KernelChoice::Direct.pick(128, 100_000), "direct");
        assert_eq!(KernelChoice::Matmul.pick(2, 10), "matmul");
        let auto = KernelChoice::Auto { crossover: 1000 };
        assert_eq!(auto.pick(10, 99), "direct");
        assert_eq!(auto.pick(10, 100), "matmul");
    }

    #[test]
    fn missing_artifact_dir_errors() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let data = blob_data(&[[0.0, 0.0]], 10);
        let config = XlaConfig {
            artifact_dir: std::path::PathBuf::from("/nonexistent"),
            ..Default::default()
        };
        assert!(XlaBackend::new(data, Prior::Niw(NiwPrior::weak(2)), config, &mut rng).is_err());
    }
}
