//! Multi-core CPU backend (the paper's Julia package analog): the data is
//! split into contiguous shards, each shard runs the restricted-Gibbs kernel
//! on a worker thread, and the per-shard sufficient statistics are reduced
//! on the coordinator thread — a shared-memory version of the distributed
//! suff-stats-only design.

use super::executor::{executor_for, Executor};
use super::shard::{
    map_shards_mut, shard_apply_merges, shard_apply_splits, shard_remap, AssignKernel, Shard,
    DEFAULT_TILE,
};
use super::{Backend, StatsBundle};
use crate::datagen::Data;
use crate::rng::Rng;
use crate::sampler::{MergeOp, ScoreGraph, SplitOp, StepParams};
use crate::stats::Prior;
use crate::util::threadpool::default_threads;
use anyhow::Result;
use std::sync::Arc;

/// Configuration for [`NativeBackend`].
#[derive(Debug, Clone)]
pub struct NativeConfig {
    /// Points per shard (also the unit of thread-level parallelism).
    pub shard_size: usize,
    /// Worker threads (defaults to core count / `DPMM_THREADS`).
    pub threads: usize,
    /// Assignment kernel (defaults to tiled; `DPMM_ASSIGN_KERNEL=scalar`
    /// selects the one-point-at-a-time correctness oracle, `=device` the
    /// multi-stream device-emulation executor).
    pub kernel: AssignKernel,
    /// Tile width for the tiled kernel (points per tile).
    pub tile: usize,
}

impl Default for NativeConfig {
    fn default() -> Self {
        Self {
            shard_size: 16 * 1024,
            threads: default_threads(),
            kernel: AssignKernel::from_env(),
            tile: DEFAULT_TILE,
        }
    }
}

/// Shared-memory multi-core backend.
pub struct NativeBackend {
    data: Arc<Data>,
    prior: Prior,
    shards: Vec<Shard>,
    threads: usize,
    /// The pluggable sweep engine resolved from `NativeConfig::kernel`
    /// (see [`crate::backend::executor`]).
    executor: Box<dyn Executor>,
}

impl NativeBackend {
    pub fn new(data: Arc<Data>, prior: Prior, config: NativeConfig, rng: &mut impl Rng) -> Self {
        let shards = data
            .shard_ranges(config.shard_size)
            .into_iter()
            .map(|range| {
                let mut shard = Shard::new(range, rng.fork());
                // Random initial sub-labels; cluster labels start at 0
                // (K_init handling is the coordinator's job via an initial
                // randomized assignment pass if K_init > 1).
                for s in shard.zsub.iter_mut() {
                    *s = (shard.rng.next_u64() & 1) as u8;
                }
                shard
            })
            .collect();
        Self {
            data,
            prior,
            shards,
            threads: config.threads.max(1),
            executor: executor_for(config.kernel, config.tile.max(1)),
        }
    }

    /// Scatter initial labels uniformly over `k` clusters (used when the fit
    /// starts from K_init > 1).
    pub fn randomize_labels(&mut self, k: usize) {
        for shard in &mut self.shards {
            for local in 0..shard.len() {
                shard.z[local] = shard.rng.next_range(k) as u32;
                shard.zsub[local] = (shard.rng.next_u64() & 1) as u8;
            }
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Map `f` over every shard via the shared scoped pool
    /// ([`map_shards_mut`]). Serves both the step pass (per-shard
    /// [`StatsBundle`]s) and the label-rewrite passes.
    fn map_shards_mut<R, F>(&mut self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(&mut Shard) -> R + Sync,
    {
        map_shards_mut(&mut self.shards, self.threads, f)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn step(&mut self, params: &StepParams) -> Result<StatsBundle> {
        // Per-sweep precomputation: flatten the snapshot into kernel
        // descriptors (W, b = W·μ, folded constants) and lower to the
        // staged kernel IR once, shared read-only by every worker thread —
        // never re-derived per shard or per point.
        let graph = ScoreGraph::lower(&params.plan());
        let data = Arc::clone(&self.data);
        let prior = self.prior.clone();
        let exec = &*self.executor;
        let bundles = map_shards_mut(&mut self.shards, self.threads, |shard| {
            exec.execute(&graph, &data, shard, &prior)
        });
        let mut total = StatsBundle::empty(&self.prior, params.k());
        for b in &bundles {
            total.merge(b);
        }
        Ok(total)
    }

    fn apply_splits(&mut self, ops: &[SplitOp]) -> Result<()> {
        self.map_shards_mut(|shard| shard_apply_splits(shard, ops));
        Ok(())
    }

    fn apply_merges(&mut self, ops: &[MergeOp]) -> Result<()> {
        self.map_shards_mut(|shard| shard_apply_merges(shard, ops));
        Ok(())
    }

    fn remap(&mut self, map: &[Option<usize>]) -> Result<()> {
        self.map_shards_mut(|shard| shard_remap(shard, map));
        Ok(())
    }

    fn labels(&self) -> Result<Vec<usize>> {
        let mut out = vec![0usize; self.data.n];
        for shard in &self.shards {
            for (local, i) in shard.range.clone().enumerate() {
                out[i] = shard.z[local] as usize;
            }
        }
        Ok(out)
    }

    fn set_labels(&mut self, labels: &[u32]) -> Result<()> {
        anyhow::ensure!(labels.len() == self.data.n, "label count mismatch");
        for shard in &mut self.shards {
            for (local, i) in shard.range.clone().enumerate() {
                shard.z[local] = labels[i];
                shard.zsub[local] = (shard.rng.next_u64() & 1) as u8;
            }
        }
        Ok(())
    }

    fn len(&self) -> usize {
        self.data.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DpmmState;
    use crate::rng::Xoshiro256pp;
    use crate::stats::NiwPrior;

    fn blob_data(centers: &[[f64; 2]], per: usize) -> Arc<Data> {
        let mut values = Vec::new();
        for (ci, c) in centers.iter().enumerate() {
            for i in 0..per {
                values.push(c[0] + 0.01 * ((i + ci) % 7) as f64);
                values.push(c[1] - 0.01 * ((i * 3 + ci) % 5) as f64);
            }
        }
        Arc::new(Data::new(centers.len() * per, 2, values))
    }

    fn state_on(centers: &[[f64; 2]], per: usize) -> DpmmState {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let mut state =
            DpmmState::new(1.0, prior.clone(), centers.len(), centers.len() * per, &mut rng);
        for (k, c) in centers.iter().enumerate() {
            let mut s = prior.empty_stats();
            for i in 0..per {
                s.add(&[c[0] + 0.01 * i as f64, c[1]]);
            }
            state.clusters[k].stats = s.clone();
            state.clusters[k].sub_stats = [s.clone(), s.clone()];
            state.clusters[k].params = prior.mean_params(&s);
            state.clusters[k].sub_params = [prior.mean_params(&s), prior.mean_params(&s)];
            state.clusters[k].weight = 1.0 / centers.len() as f64;
        }
        state
    }

    fn config(shard_size: usize, threads: usize) -> NativeConfig {
        NativeConfig { shard_size, threads, ..NativeConfig::default() }
    }

    #[test]
    fn native_step_recovers_separated_blobs() {
        let centers = [[-20.0, 0.0], [0.0, 20.0], [20.0, 0.0]];
        let data = blob_data(&centers, 200);
        let state = state_on(&centers, 200);
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let mut backend = NativeBackend::new(
            Arc::clone(&data),
            state.prior.clone(),
            config(128, 4),
            &mut rng,
        );
        assert!(backend.num_shards() > 1);
        let params = StepParams::snapshot(&state);
        let bundle = backend.step(&params).unwrap();
        let cs = bundle.cluster_stats();
        for k in 0..3 {
            assert_eq!(cs[k].count(), 200.0, "cluster {k}");
        }
        // Labels consistent with blobs.
        let labels = backend.labels().unwrap();
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(l, i / 200);
        }
    }

    #[test]
    fn native_step_deterministic_given_seed() {
        let centers = [[-20.0, 0.0], [20.0, 0.0]];
        let data = blob_data(&centers, 100);
        let state = state_on(&centers, 100);
        let params = StepParams::snapshot(&state);
        let run = |seed| {
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            let mut backend = NativeBackend::new(
                Arc::clone(&data),
                state.prior.clone(),
                config(64, 3),
                &mut rng,
            );
            backend.step(&params).unwrap();
            backend.labels().unwrap()
        };
        assert_eq!(run(42), run(42));
    }

    #[test]
    fn kernel_choice_does_not_change_labels() {
        let centers = [[-20.0, 0.0], [20.0, 0.0]];
        let data = blob_data(&centers, 150);
        let state = state_on(&centers, 150);
        let params = StepParams::snapshot(&state);
        let run = |kernel, tile| {
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            let mut backend = NativeBackend::new(
                Arc::clone(&data),
                state.prior.clone(),
                NativeConfig { shard_size: 70, threads: 2, kernel, tile },
                &mut rng,
            );
            backend.step(&params).unwrap();
            backend.labels().unwrap()
        };
        let scalar = run(AssignKernel::Scalar, DEFAULT_TILE);
        for tile in [1, 33, 128] {
            assert_eq!(run(AssignKernel::Tiled, tile), scalar, "tile={tile}");
        }
        assert_eq!(run(AssignKernel::DeviceEmu, DEFAULT_TILE), scalar, "device-emu");
    }

    #[test]
    fn split_merge_remap_roundtrip() {
        let centers = [[-20.0, 0.0], [20.0, 0.0]];
        let data = blob_data(&centers, 50);
        let state = state_on(&centers, 50);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut backend = NativeBackend::new(
            Arc::clone(&data),
            state.prior.clone(),
            config(32, 2),
            &mut rng,
        );
        backend.step(&StepParams::snapshot(&state)).unwrap();
        // Split cluster 0 → {0, 2}; all of cluster 0's points must now be
        // in 0 or 2.
        backend.apply_splits(&[SplitOp { target: 0, new_index: 2 }]).unwrap();
        let labels = backend.labels().unwrap();
        for (i, &l) in labels.iter().enumerate() {
            if i < 50 {
                assert!(l == 0 || l == 2);
            } else {
                assert_eq!(l, 1);
            }
        }
        // Merge 2 back into 0, remap {0→0, 1→1, 2→gone}.
        backend.apply_merges(&[MergeOp { keep: 0, absorb: 2 }]).unwrap();
        backend.remap(&[Some(0), Some(1), None]).unwrap();
        let labels = backend.labels().unwrap();
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(l, usize::from(i >= 50));
        }
    }

    #[test]
    fn randomize_labels_covers_all_clusters() {
        let data = blob_data(&[[0.0, 0.0]], 1000);
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let mut backend = NativeBackend::new(data, prior, config(100, 2), &mut rng);
        backend.randomize_labels(4);
        let labels = backend.labels().unwrap();
        let mut seen = [false; 4];
        for &l in &labels {
            assert!(l < 4);
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn thread_count_does_not_change_stats_totals() {
        let centers = [[-20.0, 0.0], [20.0, 0.0]];
        let data = blob_data(&centers, 300);
        let state = state_on(&centers, 300);
        let params = StepParams::snapshot(&state);
        let totals = |threads| {
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            let mut backend = NativeBackend::new(
                Arc::clone(&data),
                state.prior.clone(),
                config(64, threads),
                &mut rng,
            );
            let b = backend.step(&params).unwrap();
            b.cluster_stats().iter().map(|s| s.count()).collect::<Vec<_>>()
        };
        // Same seed → same per-shard RNGs regardless of thread count.
        assert_eq!(totals(1), totals(8));
    }
}
