//! Pluggable executors for the kernel IR ([`ScoreGraph`]): the seam
//! between *what* an assignment sweep computes (the graph's operand tables
//! and staged program) and *how* it runs.
//!
//! Three implementations, all bound by the bitwise conformance contract
//! pinned in `tests/prop_kernel_equiv.rs`:
//!
//! * [`ScalarExecutor`] — the one-point-at-a-time correctness oracle
//!   ([`shard_step_scalar`]).
//! * [`TiledExecutor`] — the production tiled/SIMD whitened-GEMM path
//!   ([`shard_step_tiled`]), fusing the graph's stages per tile.
//! * [`DeviceEmuExecutor`] — models the paper's multi-stream GPU
//!   execution: launch blocks round-robin across stream queues, each
//!   staged **upload** (transpose into a feature-major device buffer) →
//!   **launch** (batched score panel + draws on the device buffer) →
//!   **download** (label readback committed in block order), with the
//!   statistics fold on the host. It proves the graph-lowering
//!   architecture end-to-end before a real wgpu/CUDA/XLA runtime lands.
//!
//! Determinism: every executor consumes exactly two uniforms per point
//! from the shard RNG in point order (cluster draw, then sub draw) and
//! shares the bitwise score arithmetic of [`crate::linalg`], so label and
//! sub-label sequences are identical across executors under a fixed seed.
//! The device executor additionally folds statistics host-side with
//! per-point adds in point order — the exact accumulator sequence of the
//! scalar oracle — so its sufficient statistics are **bitwise**-identical
//! to the oracle's (the tiled path agrees to FP rounding; see
//! docs/DETERMINISM.md).

use super::shard::{shard_step_scalar, shard_step_tiled, AssignKernel, Shard};
use super::StatsBundle;
use crate::datagen::Data;
use crate::linalg::{dot_accumulate_tile, lower_affine_sqnorm, transpose_tile};
use crate::model::{LEFT, RIGHT};
use crate::rng::Rng;
use crate::sampler::{KernelDesc, ScoreGraph, StepPlan};
use crate::stats::Prior;

/// A backend-pluggable engine that runs one [`ScoreGraph`] sweep over one
/// shard: samples labels in place and returns the shard's statistics
/// contribution.
pub trait Executor: Send + Sync {
    /// Executor name (logs, bench tables).
    fn name(&self) -> &'static str;

    /// Run steps (e)/(f) + the statistics pass for `shard` under `graph`.
    fn execute(
        &self,
        graph: &ScoreGraph,
        data: &Data,
        shard: &mut Shard,
        prior: &Prior,
    ) -> StatsBundle;
}

/// Resolve the executor for an [`AssignKernel`] selection (`tile` is the
/// tiled path's tile width; the device executor reads its stream/block
/// geometry from `DPMM_DEVICE_STREAMS` / `DPMM_DEVICE_BLOCK`).
pub fn executor_for(kernel: AssignKernel, tile: usize) -> Box<dyn Executor> {
    match kernel {
        AssignKernel::Tiled => Box::new(TiledExecutor { tile }),
        AssignKernel::Scalar => Box::new(ScalarExecutor),
        AssignKernel::DeviceEmu => Box::new(DeviceEmuExecutor::from_env()),
    }
}

/// The one-point-at-a-time correctness oracle.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScalarExecutor;

impl Executor for ScalarExecutor {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn execute(
        &self,
        graph: &ScoreGraph,
        data: &Data,
        shard: &mut Shard,
        prior: &Prior,
    ) -> StatsBundle {
        shard_step_scalar(data, shard, &graph.plan, prior)
    }
}

/// The production tiled/SIMD whitened-GEMM path.
#[derive(Debug, Clone, Copy)]
pub struct TiledExecutor {
    /// Points per tile.
    pub tile: usize,
}

impl Executor for TiledExecutor {
    fn name(&self) -> &'static str {
        "tiled"
    }

    fn execute(
        &self,
        graph: &ScoreGraph,
        data: &Data,
        shard: &mut Shard,
        prior: &Prior,
    ) -> StatsBundle {
        shard_step_tiled(data, shard, &graph.plan, prior, self.tile)
    }
}

/// Multi-stream device-emulation executor (see module docs). Stream count
/// and block geometry are an execution choice only — results are
/// invariant to both, because uniforms are pre-drawn host-side in point
/// order and launch blocks are conditionally independent given the plan.
#[derive(Debug, Clone, Copy)]
pub struct DeviceEmuExecutor {
    /// Concurrent device stream queues (launch blocks round-robin over
    /// them; each runs on its own thread).
    pub streams: usize,
    /// Points per launch block (the emulated kernel-launch granularity).
    pub block: usize,
}

impl Default for DeviceEmuExecutor {
    fn default() -> Self {
        Self { streams: 4, block: 256 }
    }
}

impl DeviceEmuExecutor {
    /// Geometry from `DPMM_DEVICE_STREAMS` / `DPMM_DEVICE_BLOCK`
    /// (defaults 4 / 256; values must be ≥ 1). Pure speed knobs — never a
    /// results change.
    pub fn from_env() -> Self {
        let parse = |var: &str, default: usize| -> usize {
            match std::env::var(var) {
                Ok(v) => match v.trim().parse::<usize>() {
                    Ok(n) if n >= 1 => n,
                    _ => {
                        eprintln!("warning: unparsable {var}='{v}'; using {default}");
                        default
                    }
                },
                Err(_) => default,
            }
        };
        Self { streams: parse("DPMM_DEVICE_STREAMS", 4), block: parse("DPMM_DEVICE_BLOCK", 256) }
    }
}

/// Per-stream "device memory": panel scratch reused across the stream's
/// launch queue (no per-block allocation after warmup). Mirrors the tiled
/// kernel's `TileScratch` shape minus the uniform buffers (those are
/// pre-drawn host-side for the whole shard).
struct DeviceScratch {
    /// Feature-major block buffer (the uploaded tile).
    xt: Vec<f64>,
    /// Column-major `[K × T]` score panel.
    scores: Vec<f64>,
    /// GEMM output row.
    y: Vec<f64>,
    /// Per-point reduction accumulator.
    maha: Vec<f64>,
    /// Block-local member indices per cluster.
    members: Vec<Vec<u32>>,
    /// Gathered member columns for the sub-cluster panels.
    gather: Vec<f64>,
    lw_l: Vec<f64>,
    lw_r: Vec<f64>,
}

impl DeviceScratch {
    fn new(k: usize, d: usize, block: usize) -> Self {
        Self {
            xt: vec![0.0; d * block],
            scores: vec![0.0; k * block],
            y: vec![0.0; block],
            maha: vec![0.0; block],
            members: (0..k).map(|_| Vec::with_capacity(block)).collect(),
            gather: vec![0.0; d * block],
            lw_l: vec![0.0; block],
            lw_r: vec![0.0; block],
        }
    }
}

/// One emulated kernel launch: score the block's panel, draw labels and
/// sub-labels with the pre-drawn uniforms, and write them to the
/// block-local output buffers (the "device-resident" labels a download
/// commits later). Score arithmetic is the same [`crate::linalg`] kernels
/// the tiled path runs — bitwise-identical per-point results.
#[allow(clippy::too_many_arguments)]
fn launch_block(
    data: &Data,
    plan: &StepPlan,
    base: usize,
    m: usize,
    u_cl: &[f64],
    u_sub: &[f64],
    scratch: &mut DeviceScratch,
    z: &mut [u32],
    zsub: &mut [u8],
) {
    let k = plan.k();
    let d = plan.d;
    let DeviceScratch { xt, scores, y, maha, members, gather, lw_l, lw_r } = scratch;
    // Upload: host row-major → feature-major device layout.
    transpose_tile(&data.values[base * d..(base + m) * d], d, m, xt);
    // Score panel: one fused kernel per cluster row.
    for (c, desc) in plan.clusters.iter().enumerate() {
        match desc {
            KernelDesc::Gauss { w, b, c: ck } => {
                lower_affine_sqnorm(w, d, b, xt, m, y, maha);
                for t in 0..m {
                    scores[t * k + c] = ck - 0.5 * maha[t];
                }
            }
            KernelDesc::Mult { log_theta, c: ck } => {
                dot_accumulate_tile(log_theta, xt, m, maha);
                for t in 0..m {
                    scores[t * k + c] = ck + maha[t];
                }
            }
        }
    }
    // Draw: stable exp-scan per point over its unit-stride panel column —
    // identical arithmetic, and the same single uniform per point, as the
    // tiled and scalar paths.
    for t in 0..m {
        let col = &mut scores[t * k..(t + 1) * k];
        let mut best = f64::NEG_INFINITY;
        for &lw in col.iter() {
            if lw > best {
                best = lw;
            }
        }
        let mut total = 0.0;
        for e in col.iter_mut() {
            let gap = *e - best;
            let v = if gap < -36.0 { 0.0 } else { gap.exp() };
            *e = v;
            total += v;
        }
        let mut tgt = u_cl[t] * total;
        let mut zi = k - 1;
        for (c, &e) in col.iter().enumerate() {
            tgt -= e;
            if tgt < 0.0 {
                zi = c;
                break;
            }
        }
        z[t] = zi as u32;
        members[zi].push(t as u32);
    }
    // Sub-panel + sub-draw, batched per cluster over member columns.
    for (c, mem) in members.iter_mut().enumerate() {
        if mem.is_empty() {
            continue;
        }
        let mc = mem.len();
        for i in 0..d {
            let src = &xt[i * m..i * m + m];
            let dst = &mut gather[i * mc..(i + 1) * mc];
            for (g, &t) in dst.iter_mut().zip(mem.iter()) {
                *g = src[t as usize];
            }
        }
        for (h, out) in [(LEFT, &mut *lw_l), (RIGHT, &mut *lw_r)] {
            match &plan.sub[c][h] {
                KernelDesc::Gauss { w, b, c: ck } => {
                    lower_affine_sqnorm(w, d, b, gather, mc, y, maha);
                    for (o, &mh) in out[..mc].iter_mut().zip(maha.iter()) {
                        *o = ck - 0.5 * mh;
                    }
                }
                KernelDesc::Mult { log_theta, c: ck } => {
                    dot_accumulate_tile(log_theta, gather, mc, maha);
                    for (o, &acc) in out[..mc].iter_mut().zip(maha.iter()) {
                        *o = ck + acc;
                    }
                }
            }
        }
        for (idx, &t) in mem.iter().enumerate() {
            // P(right) = 1 / (1 + exp(lw_l − lw_r))
            let p_right = 1.0 / (1.0 + (lw_l[idx] - lw_r[idx]).exp());
            zsub[t as usize] = u8::from(u_sub[t as usize] < p_right);
        }
        mem.clear();
    }
}

impl Executor for DeviceEmuExecutor {
    fn name(&self) -> &'static str {
        "device-emu"
    }

    fn execute(
        &self,
        graph: &ScoreGraph,
        data: &Data,
        shard: &mut Shard,
        prior: &Prior,
    ) -> StatsBundle {
        let plan = &graph.plan;
        let k = plan.k();
        let d = plan.d;
        debug_assert_eq!(d, data.d);
        let n = shard.len();
        let block = self.block.max(1);
        // Pre-draw every uniform host-side in scalar point order (cluster
        // draw then sub draw, two per point): the shard RNG is consumed
        // exactly as the scalar oracle consumes it, so label sequences
        // stay bitwise-comparable across executors and are invariant to
        // the stream/block geometry below.
        let mut u_cl = vec![0.0; n];
        let mut u_sub = vec![0.0; n];
        for t in 0..n {
            u_cl[t] = shard.rng.next_f64();
            u_sub[t] = shard.rng.next_f64();
        }
        let n_blocks = n.div_ceil(block);
        let streams = self.streams.clamp(1, n_blocks.max(1));
        let start0 = shard.range.start;
        let timing = crate::telemetry::enabled();
        let t0 = std::time::Instant::now();
        // Launch: stream s owns blocks s, s+S, s+2S, … Blocks are
        // conditionally independent given the plan, so streams run
        // concurrently; each stages upload → launch over its queue and
        // keeps the labels in block-local buffers until download.
        let u_cl = &u_cl;
        let u_sub = &u_sub;
        let results: Vec<Vec<(usize, Vec<u32>, Vec<u8>)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..streams)
                .map(|stream| {
                    scope.spawn(move || {
                        let mut scratch = DeviceScratch::new(k, d, block);
                        let mut outs = Vec::new();
                        let mut blk = stream;
                        while blk < n_blocks {
                            let lo = blk * block;
                            let m = block.min(n - lo);
                            let mut z = vec![0u32; m];
                            let mut zsub = vec![0u8; m];
                            launch_block(
                                data,
                                plan,
                                start0 + lo,
                                m,
                                &u_cl[lo..lo + m],
                                &u_sub[lo..lo + m],
                                &mut scratch,
                                &mut z,
                                &mut zsub,
                            );
                            outs.push((blk, z, zsub));
                            blk += streams;
                        }
                        outs
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("device stream panicked")).collect()
        });
        if timing {
            crate::telemetry::catalog::sweep_phase("device_launch")
                .observe(t0.elapsed().as_secs_f64());
        }
        // Download: commit the label buffers in block order.
        let t1 = std::time::Instant::now();
        for stream_outs in &results {
            for (blk, z, zsub) in stream_outs {
                let lo = blk * block;
                shard.z[lo..lo + z.len()].copy_from_slice(z);
                shard.zsub[lo..lo + zsub.len()].copy_from_slice(zsub);
            }
        }
        // Stats fold, host-side: per-point adds in point order — the
        // scalar oracle's exact accumulator sequence, so the bundle is
        // bitwise-identical to the oracle's (the acceptance contract of
        // the conformance suite).
        let mut bundle = StatsBundle::empty(prior, k);
        for local in 0..n {
            bundle.sub_stats[shard.z[local] as usize][shard.zsub[local] as usize]
                .add(data.row(start0 + local));
        }
        if timing {
            crate::telemetry::catalog::sweep_phase("stats_fold")
                .observe(t1.elapsed().as_secs_f64());
        }
        bundle
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GmmSpec;
    use crate::model::DpmmState;
    use crate::rng::Xoshiro256pp;
    use crate::sampler::{
        sample_params, sample_sub_weights, sample_weights, SamplerOptions, StepParams,
    };
    use crate::stats::NiwPrior;

    fn fixture(n: usize, d: usize, k: usize) -> (Data, Prior, ScoreGraph) {
        let mut rng = Xoshiro256pp::seed_from_u64((n + d + k) as u64);
        let ds = GmmSpec::default_with(n, d, k).generate(&mut rng);
        let prior = Prior::Niw(NiwPrior::weak(d));
        let mut state = DpmmState::new(5.0, prior.clone(), k, n, &mut rng);
        sample_weights(&mut state, &mut rng);
        sample_sub_weights(&mut state, &mut rng);
        sample_params(&mut state, &SamplerOptions::default(), &mut rng);
        let graph = ScoreGraph::lower(&StepParams::snapshot(&state).plan());
        (ds.points, prior, graph)
    }

    #[test]
    fn device_matches_scalar_bitwise_including_stats() {
        let (data, prior, graph) = fixture(230, 3, 4);
        let mut a = Shard::new(0..data.n, Xoshiro256pp::seed_from_u64(9));
        let mut b = Shard::new(0..data.n, Xoshiro256pp::seed_from_u64(9));
        let ba = ScalarExecutor.execute(&graph, &data, &mut a, &prior);
        let bb = DeviceEmuExecutor { streams: 3, block: 64 }.execute(&graph, &data, &mut b, &prior);
        assert_eq!(a.z, b.z);
        assert_eq!(a.zsub, b.zsub);
        assert_eq!(ba.sub_stats, bb.sub_stats, "device stats must be bitwise-scalar");
    }

    #[test]
    fn device_results_invariant_to_stream_and_block_geometry() {
        let (data, prior, graph) = fixture(157, 2, 3);
        let run = |streams: usize, block: usize| {
            let mut shard = Shard::new(0..data.n, Xoshiro256pp::seed_from_u64(4));
            let bundle =
                DeviceEmuExecutor { streams, block }.execute(&graph, &data, &mut shard, &prior);
            (shard.z, shard.zsub, bundle.sub_stats)
        };
        let reference = run(1, 157);
        for (streams, block) in [(1, 1), (2, 32), (4, 64), (8, 256)] {
            assert_eq!(run(streams, block), reference, "streams={streams} block={block}");
        }
    }

    #[test]
    fn executor_for_maps_kernels() {
        assert_eq!(executor_for(AssignKernel::Tiled, 128).name(), "tiled");
        assert_eq!(executor_for(AssignKernel::Scalar, 128).name(), "scalar");
        assert_eq!(executor_for(AssignKernel::DeviceEmu, 128).name(), "device-emu");
    }
}
