//! Model/run configuration — the paper's `global_params` JSON surface
//! (`--params_path`): `alpha`, `prior_type`, prior hyperparameters,
//! `iterations`, `burn_out`, `kernel`, backend selection, seeds — plus the
//! serving-path settings consumed by `dpmm serve` / `dpmm predict`.

use crate::cli::Args;
use crate::linalg::Matrix;
use crate::sampler::SamplerOptions;
use crate::stats::{DirMultPrior, NiwPrior, Prior};
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};

/// Which likelihood/prior family to fit.
#[derive(Debug, Clone, PartialEq)]
pub enum PriorSpec {
    /// NIW prior for Gaussian components.
    Niw { kappa: f64, m: Vec<f64>, nu: f64, psi: Matrix },
    /// Symmetric-or-full Dirichlet prior for multinomial components.
    Dirichlet { alpha: Vec<f64> },
}

impl PriorSpec {
    pub fn build(&self) -> Prior {
        match self {
            PriorSpec::Niw { kappa, m, nu, psi } => {
                Prior::Niw(NiwPrior::new(*kappa, m.clone(), *nu, psi.clone()))
            }
            PriorSpec::Dirichlet { alpha } => Prior::DirMult(DirMultPrior::new(alpha.clone())),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            PriorSpec::Niw { m, .. } => m.len(),
            PriorSpec::Dirichlet { alpha } => alpha.len(),
        }
    }
}

/// Which backend executes the label/statistics pass.
#[derive(Debug, Clone, PartialEq)]
pub enum BackendChoice {
    /// Multi-core CPU (paper: Julia package).
    Native { threads: usize, shard_size: usize },
    /// AOT XLA artifacts via PJRT (paper: CUDA/C++ package).
    Xla { artifact_dir: String, shard_size: usize, kernel: String, crossover: usize },
    /// TCP workers (paper: multi-machine Julia).
    Distributed { workers: Vec<String>, worker_threads: usize },
}

impl Default for BackendChoice {
    fn default() -> Self {
        BackendChoice::Native { threads: 0, shard_size: 16 * 1024 }
    }
}

/// Settings for the online-inference serving path (`dpmm serve` and the
/// engine-direct mode of `dpmm predict`); see [`crate::serve`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeSettings {
    /// Listen address (`host:port`; port 0 = ephemeral).
    pub addr: String,
    /// Engine worker threads (0 = core count / `DPMM_THREADS`).
    pub threads: usize,
    /// Points per scoring tile.
    pub tile: usize,
    /// Cap on coalesced points per fused micro-batch pass.
    pub max_batch_points: usize,
    /// Optional plain-TCP Prometheus text listener (`host:port`; port 0 =
    /// ephemeral). `None` = no scrape listener — the serve-wire `Metrics`
    /// verb still answers on the main address.
    pub metrics_addr: Option<String>,
    /// Scoring arithmetic width (`f64` default; `f32` opts into the
    /// reduced-precision serving path — see [`crate::serve::Precision`]
    /// for the tolerance contract). Fitting always runs f64.
    pub precision: crate::serve::Precision,
}

impl Default for ServeSettings {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7979".into(),
            threads: 0,
            tile: crate::backend::shard::DEFAULT_TILE,
            max_batch_points: 64 * 1024,
            metrics_addr: None,
            precision: crate::serve::Precision::F64,
        }
    }
}

impl ServeSettings {
    /// Parse `--addr / --threads / --tile / --batch_points /
    /// --metrics_addr / --precision` CLI overrides. `--precision` falls
    /// back to the `DPMM_SERVE_PRECISION` env var when absent.
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut s = ServeSettings::default();
        if let Some(a) = args.get("addr") {
            s.addr = a.to_string();
        }
        if let Some(t) = args.get_usize("threads")? {
            s.threads = t;
        }
        if let Some(t) = args.get_usize("tile")? {
            s.tile = t.max(1);
        }
        if let Some(b) = args.get_usize("batch_points")? {
            s.max_batch_points = b.max(1);
        }
        if let Some(m) = args.get("metrics_addr") {
            s.metrics_addr = Some(m.to_string());
        }
        let precision = args
            .get("precision")
            .map(str::to_string)
            .or_else(|| std::env::var("DPMM_SERVE_PRECISION").ok());
        if let Some(p) = precision {
            s.precision = p.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        }
        Ok(s)
    }
}

/// Settings for the streaming ingestion path (`dpmm stream`); maps onto
/// [`crate::stream::StreamConfig`] (single machine) or
/// [`crate::stream::DistributedStreamConfig`] (when `--workers` is given)
/// plus the serving knobs it rides with.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSettings {
    /// Sliding-window capacity in points (global across workers in
    /// distributed mode).
    pub window: usize,
    /// Restricted-Gibbs sweeps over the window per ingested batch.
    pub sweeps: usize,
    /// Exponential forgetting factor per ingest (1.0 = off).
    pub decay: f64,
    /// DP concentration α for the restricted sweeps.
    pub alpha: f64,
    /// RNG seed for the sweep streams.
    pub seed: u64,
    /// Distributed ingest workers (`host:port` running `dpmm worker`;
    /// empty = single-process streaming).
    pub workers: Vec<String>,
    /// Read-replica endpoints (`host:port` running `dpmm replica`) the
    /// leader fans each published snapshot generation out to; empty = no
    /// replication. Falls back to the `DPMM_REPLICAS` env var
    /// (comma-separated) when the `--replicas` flag is absent.
    pub replicas: Vec<String>,
    /// Sweep threads per worker process (distributed mode only).
    pub worker_threads: usize,
    /// Streaming-state checkpoint file (leader durability); written
    /// atomically every `checkpoint_every` ingested batches.
    pub checkpoint_path: Option<String>,
    /// Checkpoint cadence in ingested batches (0 = never periodic).
    pub checkpoint_every: usize,
    /// Resume from `checkpoint_path` instead of seeding fresh from
    /// `--checkpoint`/`--snapshot` (bitwise-identical replay).
    pub resume: bool,
    /// Heartbeat probe interval in ms (distributed mode; 0 = supervision
    /// off, the default — failures are then detected reactively mid-sweep).
    pub heartbeat_ms: u64,
    /// Silence tolerated before a worker is rated `Dead` and proactively
    /// evicted (must be ≥ the probe interval to allow at least one retry).
    pub heartbeat_grace_ms: u64,
    /// Max connect/session-open attempts per worker (≥ 1; transient
    /// failures are retried with exponential backoff, fatal ones are not).
    pub connect_retries: usize,
    /// Base backoff delay before the first retry, in ms.
    pub retry_base_ms: u64,
    /// Backoff delay cap, in ms.
    pub retry_max_ms: u64,
}

impl Default for StreamSettings {
    fn default() -> Self {
        Self {
            window: 32 * 1024,
            sweeps: 2,
            decay: 1.0,
            alpha: 10.0,
            seed: 0,
            workers: Vec::new(),
            replicas: Vec::new(),
            worker_threads: 1,
            checkpoint_path: None,
            checkpoint_every: 16,
            resume: false,
            heartbeat_ms: 0,
            heartbeat_grace_ms: 3000,
            connect_retries: 3,
            retry_base_ms: 50,
            retry_max_ms: 2000,
        }
    }
}

impl StreamSettings {
    /// Parse `--window / --sweeps / --decay / --alpha / --seed /
    /// --workers / --replicas / --worker_threads / --checkpoint_path /
    /// --checkpoint_every / --resume / --heartbeat_ms /
    /// --heartbeat_grace_ms / --connect_retries / --retry_base_ms /
    /// --retry_max_ms` overrides. `--replicas` falls back to the
    /// `DPMM_REPLICAS` env var so a fleet's endpoint list can live in the
    /// deploy environment instead of every launch command.
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut s = StreamSettings { workers: args.get_list("workers"), ..Default::default() };
        s.replicas = args.get_list("replicas");
        if s.replicas.is_empty() {
            if let Ok(env) = std::env::var("DPMM_REPLICAS") {
                s.replicas = env
                    .split(',')
                    .map(str::trim)
                    .filter(|a| !a.is_empty())
                    .map(str::to_string)
                    .collect();
            }
        }
        if let Some(wt) = args.get_usize("worker_threads")? {
            s.worker_threads = wt.max(1);
        }
        if let Some(hb) = args.get_u64("heartbeat_ms")? {
            s.heartbeat_ms = hb;
        }
        if let Some(g) = args.get_u64("heartbeat_grace_ms")? {
            s.heartbeat_grace_ms = g;
        }
        if s.heartbeat_ms > 0 && s.heartbeat_grace_ms < s.heartbeat_ms {
            bail!(
                "--heartbeat_grace_ms ({}) must be >= --heartbeat_ms ({}) so a \
                 worker gets at least one full probe interval before eviction",
                s.heartbeat_grace_ms,
                s.heartbeat_ms
            );
        }
        if let Some(r) = args.get_usize("connect_retries")? {
            s.connect_retries = r.max(1);
        }
        if let Some(b) = args.get_u64("retry_base_ms")? {
            s.retry_base_ms = b;
        }
        if let Some(m) = args.get_u64("retry_max_ms")? {
            s.retry_max_ms = m;
        }
        if let Some(cp) = args.get("checkpoint_path") {
            s.checkpoint_path = Some(cp.to_string());
        }
        if let Some(ce) = args.get_usize("checkpoint_every")? {
            s.checkpoint_every = ce;
        }
        s.resume = args.flag("resume");
        if s.resume && s.checkpoint_path.is_none() {
            bail!("--resume needs --checkpoint_path=<stream.ckpt> to resume from");
        }
        if let Some(w) = args.get_usize("window")? {
            s.window = w.max(1);
        }
        if let Some(r) = args.get_usize("sweeps")? {
            s.sweeps = r;
        }
        if let Some(d) = args.get_f64("decay")? {
            if !(d > 0.0 && d <= 1.0) {
                bail!("--decay must be in (0, 1], got {d}");
            }
            s.decay = d;
        }
        if let Some(a) = args.get_f64("alpha")? {
            if a <= 0.0 {
                bail!("--alpha must be positive, got {a}");
            }
            s.alpha = a;
        }
        if let Some(seed) = args.get_u64("seed")? {
            s.seed = seed;
        }
        Ok(s)
    }
}

/// Everything a fit needs (the paper's JSON `global_params`).
#[derive(Debug, Clone)]
pub struct DpmmParams {
    pub alpha: f64,
    pub prior: PriorSpec,
    pub iterations: usize,
    /// Paper's `burn_out`: age (iterations) before a cluster may split/merge.
    pub burnout: usize,
    /// Initial number of clusters.
    pub initial_clusters: usize,
    pub max_clusters: usize,
    pub seed: u64,
    pub backend: BackendChoice,
    /// Stop split/merge moves for the trailing iterations so labels settle.
    pub final_polish_iters: usize,
    /// Print per-iteration progress.
    pub verbose: bool,
    /// Write a resumable checkpoint here every `checkpoint_every` iterations
    /// (the paper's JLD2 save/restore feature).
    pub checkpoint_path: Option<String>,
    pub checkpoint_every: usize,
}

impl DpmmParams {
    /// Gaussian defaults with a weak NIW prior — the paper's "let the data
    /// speak" setting (§2.2 Example 3).
    pub fn gaussian_default(d: usize) -> Self {
        Self {
            alpha: 10.0,
            prior: PriorSpec::Niw {
                kappa: 1.0,
                m: vec![0.0; d],
                nu: d as f64 + 3.0,
                psi: Matrix::identity(d),
            },
            iterations: 100,
            burnout: 5,
            initial_clusters: 1,
            max_clusters: 48,
            seed: 0,
            backend: BackendChoice::default(),
            final_polish_iters: 5,
            verbose: false,
            checkpoint_path: None,
            checkpoint_every: 25,
        }
    }

    /// Multinomial defaults with a symmetric Dirichlet prior.
    pub fn multinomial_default(d: usize) -> Self {
        Self {
            alpha: 10.0,
            prior: PriorSpec::Dirichlet { alpha: vec![1.0; d] },
            ..Self::gaussian_default(d)
        }
    }

    pub fn sampler_options(&self) -> SamplerOptions {
        SamplerOptions {
            burnout: self.burnout,
            no_splits: false,
            no_merges: false,
            max_clusters: self.max_clusters,
            sub_restart_every: 10,
        }
    }

    /// Parse the paper-style JSON params file. Minimal example:
    ///
    /// ```json
    /// {
    ///   "alpha": 10.0,
    ///   "prior_type": "Gaussian",
    ///   "prior": {"kappa": 1.0, "m": [0, 0], "nu": 5.0, "psi": [1, 0, 0, 1]},
    ///   "iterations": 100,
    ///   "burn_out": 5
    /// }
    /// ```
    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text).context("parsing params JSON")?;
        let prior_type = v
            .get("prior_type")
            .and_then(Json::as_str)
            .unwrap_or("Gaussian")
            .to_ascii_lowercase();
        let pv = v.get("prior").ok_or_else(|| anyhow!("params missing 'prior'"))?;
        let prior = match prior_type.as_str() {
            "gaussian" => {
                let m = pv
                    .get("m")
                    .and_then(Json::as_f64_vec)
                    .ok_or_else(|| anyhow!("gaussian prior needs 'm' (mean vector)"))?;
                let d = m.len();
                let kappa = pv.get("kappa").and_then(Json::as_f64).unwrap_or(1.0);
                let nu = pv.get("nu").and_then(Json::as_f64).unwrap_or(d as f64 + 3.0);
                let psi_flat = pv
                    .get("psi")
                    .and_then(Json::as_f64_vec)
                    .unwrap_or_else(|| Matrix::identity(d).data().to_vec());
                if psi_flat.len() != d * d {
                    bail!("psi must have d*d = {} entries, got {}", d * d, psi_flat.len());
                }
                PriorSpec::Niw { kappa, m, nu, psi: Matrix::from_vec(d, d, psi_flat) }
            }
            "multinomial" => {
                let alpha = pv
                    .get("alpha")
                    .and_then(Json::as_f64_vec)
                    .or_else(|| {
                        // {"alpha": 1.0, "dim": 64} shorthand
                        let a0 = pv.get("alpha").and_then(Json::as_f64)?;
                        let d = pv.get("dim").and_then(Json::as_usize)?;
                        Some(vec![a0; d])
                    })
                    .ok_or_else(|| anyhow!("multinomial prior needs 'alpha' (vector or scalar + 'dim')"))?;
                PriorSpec::Dirichlet { alpha }
            }
            other => bail!("unknown prior_type '{other}' (Gaussian | Multinomial)"),
        };
        let d = prior.dim();
        let mut p = match prior {
            PriorSpec::Niw { .. } => DpmmParams::gaussian_default(d),
            PriorSpec::Dirichlet { .. } => DpmmParams::multinomial_default(d),
        };
        p.prior = prior;
        if let Some(a) = v.get("alpha").and_then(Json::as_f64) {
            if a <= 0.0 {
                bail!("alpha must be positive");
            }
            p.alpha = a;
        }
        if let Some(i) = v.get("iterations").and_then(Json::as_usize) {
            p.iterations = i;
        }
        if let Some(b) = v.get("burn_out").and_then(Json::as_usize) {
            p.burnout = b;
        }
        if let Some(k) = v.get("initial_clusters").and_then(Json::as_usize) {
            p.initial_clusters = k.max(1);
        }
        if let Some(k) = v.get("max_clusters").and_then(Json::as_usize) {
            p.max_clusters = k;
        }
        if let Some(s) = v.get("seed").and_then(Json::as_i64) {
            p.seed = s as u64;
        }
        if let Some(fp) = v.get("final_polish_iters").and_then(Json::as_usize) {
            p.final_polish_iters = fp;
        }
        if let Some(b) = v.get("verbose").and_then(Json::as_bool) {
            p.verbose = b;
        }
        if let Some(cp) = v.get("checkpoint_path").and_then(Json::as_str) {
            p.checkpoint_path = Some(cp.to_string());
        }
        if let Some(ce) = v.get("checkpoint_every").and_then(Json::as_usize) {
            p.checkpoint_every = ce;
        }
        // Backend block (optional).
        if let Some(bk) = v.get("backend") {
            let kind = bk.get("kind").and_then(Json::as_str).unwrap_or("native");
            p.backend = match kind {
                "native" => BackendChoice::Native {
                    threads: bk.get("threads").and_then(Json::as_usize).unwrap_or(0),
                    shard_size: bk.get("shard_size").and_then(Json::as_usize).unwrap_or(16 * 1024),
                },
                "xla" => BackendChoice::Xla {
                    artifact_dir: bk
                        .get("artifact_dir")
                        .and_then(Json::as_str)
                        .unwrap_or("artifacts")
                        .to_string(),
                    shard_size: bk.get("shard_size").and_then(Json::as_usize).unwrap_or(4096),
                    kernel: bk.get("kernel").and_then(Json::as_str).unwrap_or("auto").to_string(),
                    crossover: bk
                        .get("crossover")
                        .and_then(Json::as_usize)
                        .unwrap_or(640_000),
                },
                "distributed" => BackendChoice::Distributed {
                    workers: bk
                        .get("workers")
                        .and_then(Json::as_arr)
                        .map(|a| {
                            a.iter().filter_map(Json::as_str).map(str::to_string).collect()
                        })
                        .unwrap_or_default(),
                    worker_threads: bk.get("worker_threads").and_then(Json::as_usize).unwrap_or(1),
                },
                other => bail!("unknown backend kind '{other}'"),
            };
        }
        Ok(p)
    }

    /// Serialize back to the params-JSON dialect (round-trip for tooling).
    pub fn to_json(&self) -> Json {
        let prior = match &self.prior {
            PriorSpec::Niw { kappa, m, nu, psi } => Json::obj(vec![
                ("kappa", (*kappa).into()),
                ("m", Json::arr_f64(m)),
                ("nu", (*nu).into()),
                ("psi", Json::arr_f64(psi.data())),
            ]),
            PriorSpec::Dirichlet { alpha } => Json::obj(vec![("alpha", Json::arr_f64(alpha))]),
        };
        let prior_type = match &self.prior {
            PriorSpec::Niw { .. } => "Gaussian",
            PriorSpec::Dirichlet { .. } => "Multinomial",
        };
        Json::obj(vec![
            ("alpha", self.alpha.into()),
            ("prior_type", prior_type.into()),
            ("prior", prior),
            ("iterations", self.iterations.into()),
            ("burn_out", self.burnout.into()),
            ("initial_clusters", self.initial_clusters.into()),
            ("max_clusters", self.max_clusters.into()),
            ("seed", (self.seed as usize).into()),
            ("final_polish_iters", self.final_polish_iters.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_json_roundtrip() {
        let text = r#"{
            "alpha": 4.5,
            "prior_type": "Gaussian",
            "prior": {"kappa": 2.0, "m": [1, 2], "nu": 6.0, "psi": [2, 0, 0, 2]},
            "iterations": 42,
            "burn_out": 3,
            "seed": 7
        }"#;
        let p = DpmmParams::from_json(text).unwrap();
        assert_eq!(p.alpha, 4.5);
        assert_eq!(p.iterations, 42);
        assert_eq!(p.burnout, 3);
        assert_eq!(p.seed, 7);
        match &p.prior {
            PriorSpec::Niw { kappa, m, nu, psi } => {
                assert_eq!(*kappa, 2.0);
                assert_eq!(m, &vec![1.0, 2.0]);
                assert_eq!(*nu, 6.0);
                assert_eq!(psi[(1, 1)], 2.0);
            }
            _ => panic!("wrong prior"),
        }
        // Round-trip through to_json.
        let text2 = json::to_string(&p.to_json());
        let p2 = DpmmParams::from_json(&text2).unwrap();
        assert_eq!(p2.alpha, p.alpha);
        assert_eq!(p2.prior, p.prior);
    }

    #[test]
    fn multinomial_scalar_alpha_shorthand() {
        let text = r#"{
            "prior_type": "Multinomial",
            "prior": {"alpha": 0.5, "dim": 8}
        }"#;
        let p = DpmmParams::from_json(text).unwrap();
        match &p.prior {
            PriorSpec::Dirichlet { alpha } => assert_eq!(alpha, &vec![0.5; 8]),
            _ => panic!("wrong prior"),
        }
    }

    #[test]
    fn backend_blocks_parse() {
        let text = r#"{
            "prior_type": "Gaussian",
            "prior": {"m": [0, 0]},
            "backend": {"kind": "xla", "artifact_dir": "arts", "kernel": "direct"}
        }"#;
        let p = DpmmParams::from_json(text).unwrap();
        match &p.backend {
            BackendChoice::Xla { artifact_dir, kernel, .. } => {
                assert_eq!(artifact_dir, "arts");
                assert_eq!(kernel, "direct");
            }
            _ => panic!("wrong backend"),
        }
        let text = r#"{
            "prior_type": "Gaussian",
            "prior": {"m": [0]},
            "backend": {"kind": "distributed", "workers": ["a:1", "b:2"], "worker_threads": 3}
        }"#;
        match DpmmParams::from_json(text).unwrap().backend {
            BackendChoice::Distributed { workers, worker_threads } => {
                assert_eq!(workers, vec!["a:1", "b:2"]);
                assert_eq!(worker_threads, 3);
            }
            _ => panic!("wrong backend"),
        }
    }

    #[test]
    fn serve_settings_from_args() {
        let args = Args::parse(
            ["serve", "--addr=0.0.0.0:9000", "--threads=4", "--batch_points=128"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        let s = ServeSettings::from_args(&args).unwrap();
        assert_eq!(s.addr, "0.0.0.0:9000");
        assert_eq!(s.threads, 4);
        assert_eq!(s.max_batch_points, 128);
        assert_eq!(s.tile, ServeSettings::default().tile);
        assert_eq!(s.metrics_addr, None);
        let with_metrics = Args::parse(
            ["serve", "--metrics_addr=127.0.0.1:9464"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        let s = ServeSettings::from_args(&with_metrics).unwrap();
        assert_eq!(s.metrics_addr.as_deref(), Some("127.0.0.1:9464"));
        let bad = Args::parse(
            ["serve", "--threads=nope"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        assert!(ServeSettings::from_args(&bad).is_err());
        let f32_args = Args::parse(
            ["serve", "--precision=f32"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        let s = ServeSettings::from_args(&f32_args).unwrap();
        assert_eq!(s.precision, crate::serve::Precision::F32);
        let bad_precision = Args::parse(
            ["serve", "--precision=f16"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        assert!(ServeSettings::from_args(&bad_precision).is_err());
    }

    #[test]
    fn stream_settings_from_args() {
        let args = Args::parse(
            ["stream", "--window=4096", "--sweeps=3", "--decay=0.97", "--alpha=5"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        let s = StreamSettings::from_args(&args).unwrap();
        assert_eq!(s.window, 4096);
        assert_eq!(s.sweeps, 3);
        assert_eq!(s.decay, 0.97);
        assert_eq!(s.alpha, 5.0);
        assert_eq!(s.seed, StreamSettings::default().seed);
        assert!(s.workers.is_empty(), "no --workers ⇒ single-process streaming");
        let cluster = Args::parse(
            ["stream", "--workers=h1:7878, h2:7878", "--worker_threads=4"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        let s = StreamSettings::from_args(&cluster).unwrap();
        assert_eq!(s.workers, vec!["h1:7878", "h2:7878"]);
        assert_eq!(s.worker_threads, 4);
        assert!(s.checkpoint_path.is_none());
        assert!(!s.resume);
        assert!(s.replicas.is_empty(), "no --replicas ⇒ no snapshot fan-out");
        let replicated = Args::parse(
            ["stream", "--replicas=r1:8001, r2:8002"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        let s = StreamSettings::from_args(&replicated).unwrap();
        assert_eq!(s.replicas, vec!["r1:8001", "r2:8002"]);
        let durable = Args::parse(
            ["stream", "--checkpoint_path=st.ckpt", "--checkpoint_every=4", "--resume"]
                .iter()
                .map(|s| s.to_string()),
            &["resume"],
        )
        .unwrap();
        let s = StreamSettings::from_args(&durable).unwrap();
        assert_eq!(s.checkpoint_path.as_deref(), Some("st.ckpt"));
        assert_eq!(s.checkpoint_every, 4);
        assert!(s.resume);
        // --resume without a checkpoint path is a config error.
        let bad = Args::parse(
            ["stream", "--resume"].iter().map(|s| s.to_string()),
            &["resume"],
        )
        .unwrap();
        assert!(StreamSettings::from_args(&bad).is_err());
        for bad in ["--decay=0", "--decay=1.5", "--alpha=-2"] {
            let args = Args::parse(
                ["stream", bad].iter().map(|s| s.to_string()),
                &[],
            )
            .unwrap();
            assert!(StreamSettings::from_args(&args).is_err(), "{bad}");
        }
    }

    #[test]
    fn stream_supervision_settings_from_args() {
        let s = StreamSettings::from_args(
            &Args::parse(["stream"].iter().map(|s| s.to_string()), &[]).unwrap(),
        )
        .unwrap();
        assert_eq!(s.heartbeat_ms, 0, "supervision is off by default");
        assert_eq!(s.heartbeat_grace_ms, 3000);
        assert_eq!(s.connect_retries, 3);
        let args = Args::parse(
            [
                "stream",
                "--heartbeat_ms=200",
                "--heartbeat_grace_ms=900",
                "--connect_retries=5",
                "--retry_base_ms=10",
                "--retry_max_ms=400",
            ]
            .iter()
            .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        let s = StreamSettings::from_args(&args).unwrap();
        assert_eq!(s.heartbeat_ms, 200);
        assert_eq!(s.heartbeat_grace_ms, 900);
        assert_eq!(s.connect_retries, 5);
        assert_eq!(s.retry_base_ms, 10);
        assert_eq!(s.retry_max_ms, 400);
        // Grace shorter than the probe interval would evict a worker before
        // its first missed probe could be retried.
        let bad = Args::parse(
            ["stream", "--heartbeat_ms=500", "--heartbeat_grace_ms=100"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        assert!(StreamSettings::from_args(&bad).is_err());
        // connect_retries is clamped to at least one attempt.
        let one = Args::parse(
            ["stream", "--connect_retries=0"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        assert_eq!(StreamSettings::from_args(&one).unwrap().connect_retries, 1);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(DpmmParams::from_json("{").is_err());
        assert!(DpmmParams::from_json(r#"{"prior_type": "Poisson", "prior": {}}"#).is_err());
        assert!(DpmmParams::from_json(
            r#"{"prior_type": "Gaussian", "prior": {"m": [0,0], "psi": [1,2,3]}}"#
        )
        .is_err());
        assert!(DpmmParams::from_json(
            r#"{"alpha": -1, "prior_type": "Gaussian", "prior": {"m": [0]}}"#
        )
        .is_err());
    }
}
