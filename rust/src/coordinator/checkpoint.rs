//! Fit checkpointing (the paper's Julia package uses JLD2 to save/restore
//! chains; here the coordinator state + labels serialize through the same
//! binary codec as the wire protocol, so a multi-hour fit on a large corpus
//! can resume after interruption).
//!
//! Layout: `[magic][version][alpha][prior][K × cluster][iter][labels]`.
//! Labels are stored coordinator-side in the file even though they live in
//! the backend at run time — on restore they are pushed back via a remap.
//!
//! Version byte: **1** = fit checkpoint (this module); **3** = streaming
//! checkpoint — the same model section followed by a streaming-state
//! section (`crate::stream::checkpoint`; v2 was never shipped). Fit and
//! serve loaders keep accepting v1 unchanged, and
//! [`crate::serve::ModelSnapshot::from_checkpoint_file`] reads the model
//! section of either version.

use crate::model::{Cluster, DpmmState};
use crate::stats::{Params, Prior, Stats};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

pub(crate) const MAGIC: &[u8; 8] = b"DPMMCKPT";
pub(crate) const VERSION: u8 = 1;

pub(crate) fn write_stats(w: &mut impl Write, s: &Stats) -> Result<()> {
    match s {
        Stats::Gauss(g) => {
            w.write_all(&[0u8])?;
            w.write_all(&g.n.to_le_bytes())?;
            write_f64s(w, &g.sum_x)?;
            w.write_all(&(g.sum_xxt.rows() as u32).to_le_bytes())?;
            write_f64s(w, g.sum_xxt.data())?;
        }
        Stats::Mult(m) => {
            w.write_all(&[1u8])?;
            w.write_all(&m.n.to_le_bytes())?;
            write_f64s(w, &m.sum_x)?;
        }
    }
    Ok(())
}

pub(crate) fn read_stats(r: &mut impl Read) -> Result<Stats> {
    let tag = read_u8(r)?;
    Ok(match tag {
        0 => {
            let n = read_f64(r)?;
            let sum_x = read_f64s(r)?;
            let rows = read_u32(r)? as usize;
            let data = read_f64s(r)?;
            if data.len() != rows * rows {
                bail!("checkpoint scatter matrix shape mismatch");
            }
            Stats::Gauss(crate::stats::NiwStats {
                n,
                sum_x,
                sum_xxt: crate::linalg::Matrix::from_vec(rows, rows, data),
            })
        }
        1 => {
            let n = read_f64(r)?;
            let sum_x = read_f64s(r)?;
            Stats::Mult(crate::stats::DirMultStats { n, sum_x })
        }
        t => bail!("bad stats tag {t} in checkpoint"),
    })
}

pub(crate) fn write_f64s(w: &mut impl Write, v: &[f64]) -> Result<()> {
    w.write_all(&(v.len() as u32).to_le_bytes())?;
    for x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn read_f64s(r: &mut impl Read) -> Result<Vec<f64>> {
    let n = read_u32(r)? as usize;
    if n > 1 << 28 {
        bail!("checkpoint vector too large ({n})");
    }
    (0..n).map(|_| read_f64(r)).collect()
}

pub(crate) fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub(crate) fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn read_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

pub(crate) fn write_prior(w: &mut impl Write, p: &Prior) -> Result<()> {
    match p {
        Prior::Niw(n) => {
            w.write_all(&[0u8])?;
            w.write_all(&n.kappa.to_le_bytes())?;
            write_f64s(w, &n.m)?;
            w.write_all(&n.nu.to_le_bytes())?;
            write_f64s(w, n.psi.data())?;
        }
        Prior::DirMult(d) => {
            w.write_all(&[1u8])?;
            write_f64s(w, &d.alpha)?;
        }
    }
    Ok(())
}

pub(crate) fn read_prior(r: &mut impl Read) -> Result<Prior> {
    // Validate hyperparameters *before* the constructors: their `assert!`s
    // are for programmer errors, and a corrupt checkpoint/snapshot file must
    // surface as an error, not abort the loading process.
    Ok(match read_u8(r)? {
        0 => {
            let kappa = read_f64(r)?;
            let m = read_f64s(r)?;
            let nu = read_f64(r)?;
            let psi_flat = read_f64s(r)?;
            let d = m.len();
            if psi_flat.len() != d * d {
                bail!("checkpoint psi shape mismatch");
            }
            if d == 0 || !kappa.is_finite() || kappa <= 0.0 {
                bail!("checkpoint NIW prior has invalid kappa {kappa} (d={d})");
            }
            if !nu.is_finite() || nu <= (d as f64) - 1.0 {
                bail!("checkpoint NIW prior has invalid nu {nu} for d={d}");
            }
            if m.iter().any(|v| !v.is_finite()) || psi_flat.iter().any(|v| !v.is_finite()) {
                bail!("checkpoint NIW prior has non-finite hyperparameters");
            }
            Prior::Niw(crate::stats::NiwPrior::new(
                kappa,
                m,
                nu,
                crate::linalg::Matrix::from_vec(d, d, psi_flat),
            ))
        }
        1 => {
            let alpha = read_f64s(r)?;
            if alpha.is_empty() || alpha.iter().any(|&a| !a.is_finite() || a <= 0.0) {
                bail!("checkpoint Dirichlet prior has invalid concentration vector");
            }
            Prior::DirMult(crate::stats::DirMultPrior::new(alpha))
        }
        t => bail!("bad prior tag {t} in checkpoint"),
    })
}

/// A resumable snapshot of a fit.
#[derive(Debug)]
pub struct Checkpoint {
    pub state: DpmmState,
    /// Completed iterations.
    pub iter: usize,
    /// Full label vector (original data order).
    pub labels: Vec<u32>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = std::io::BufWriter::new(
            std::fs::File::create(path.as_ref())
                .with_context(|| format!("creating {}", path.as_ref().display()))?,
        );
        w.write_all(MAGIC)?;
        w.write_all(&[VERSION])?;
        w.write_all(&self.state.alpha.to_le_bytes())?;
        w.write_all(&(self.state.n_total as u64).to_le_bytes())?;
        write_prior(&mut w, &self.state.prior)?;
        w.write_all(&(self.state.k() as u32).to_le_bytes())?;
        for c in &self.state.clusters {
            write_stats(&mut w, &c.stats)?;
            write_stats(&mut w, &c.sub_stats[0])?;
            write_stats(&mut w, &c.sub_stats[1])?;
            w.write_all(&c.weight.to_le_bytes())?;
            w.write_all(&c.sub_weights[0].to_le_bytes())?;
            w.write_all(&c.sub_weights[1].to_le_bytes())?;
            w.write_all(&(c.age as u64).to_le_bytes())?;
        }
        w.write_all(&(self.iter as u64).to_le_bytes())?;
        w.write_all(&(self.labels.len() as u64).to_le_bytes())?;
        for &l in &self.labels {
            w.write_all(&l.to_le_bytes())?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>, rng: &mut impl crate::rng::Rng) -> Result<Checkpoint> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a dpmm checkpoint (bad magic)");
        }
        let ver = read_u8(&mut r)?;
        if ver == crate::stream::checkpoint::STREAM_CHECKPOINT_VERSION {
            bail!(
                "this is a streaming checkpoint (version {ver}) — resume it with \
                 `dpmm stream --resume`, or serve from it directly; it cannot seed \
                 a batch fit (it has no full label vector)"
            );
        }
        if ver != VERSION {
            bail!("unsupported checkpoint version {ver}");
        }
        let alpha = read_f64(&mut r)?;
        let n_total = read_u64(&mut r)? as usize;
        let prior = read_prior(&mut r)?;
        let k = read_u32(&mut r)? as usize;
        if k == 0 || k > 1 << 16 {
            bail!("implausible cluster count {k} in checkpoint");
        }
        let mut state = DpmmState::new(alpha, prior.clone(), 1, n_total, rng);
        state.clusters.clear();
        for _ in 0..k {
            let stats = read_stats(&mut r)?;
            let sub_l = read_stats(&mut r)?;
            let sub_r = read_stats(&mut r)?;
            let weight = read_f64(&mut r)?;
            let sw0 = read_f64(&mut r)?;
            let sw1 = read_f64(&mut r)?;
            let age = read_u64(&mut r)? as usize;
            // Params are resampled from the restored statistics on the
            // first post-restore sweep (they are posterior draws anyway).
            let params = prior.sample_params(&stats, rng);
            let sub_params =
                [prior.sample_params(&sub_l, rng), prior.sample_params(&sub_r, rng)];
            state.clusters.push(Cluster {
                stats,
                sub_stats: [sub_l, sub_r],
                params,
                sub_params,
                weight,
                sub_weights: [sw0, sw1],
                age,
                since_restart: 0,
            });
        }
        let iter = read_u64(&mut r)? as usize;
        let n_labels = read_u64(&mut r)? as usize;
        if n_labels != n_total {
            bail!("checkpoint label count {n_labels} != N {n_total}");
        }
        let mut labels = Vec::with_capacity(n_labels);
        for _ in 0..n_labels {
            labels.push(read_u32(&mut r)?);
        }
        if labels.iter().any(|&l| l as usize >= k) {
            bail!("checkpoint label out of range");
        }
        Ok(Checkpoint { state, iter, labels })
    }
}

// Touch Params so the import is used in docs/links.
#[allow(dead_code)]
fn _t(_: Option<Params>) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::stats::NiwPrior;

    fn make_state() -> DpmmState {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut state = DpmmState::new(3.5, prior.clone(), 2, 6, &mut rng);
        for (ci, c) in state.clusters.iter_mut().enumerate() {
            let mut s = prior.empty_stats();
            s.add(&[ci as f64, 1.0]);
            s.add(&[ci as f64 + 0.5, -1.0]);
            s.add(&[ci as f64 - 0.5, 0.0]);
            c.stats = s.clone();
            c.sub_stats = [s.clone(), prior.empty_stats()];
            c.weight = if ci == 0 { 0.7 } else { 0.3 };
            c.sub_weights = [0.6, 0.4];
            c.age = 7 + ci;
        }
        state
    }

    #[test]
    fn roundtrip_gaussian_checkpoint() {
        let state = make_state();
        let ckpt = Checkpoint { state, iter: 42, labels: vec![0, 0, 0, 1, 1, 1] };
        let p = std::env::temp_dir().join(format!("dpmm_ckpt_{}.bin", std::process::id()));
        ckpt.save(&p).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let back = Checkpoint::load(&p, &mut rng).unwrap();
        assert_eq!(back.iter, 42);
        assert_eq!(back.labels, ckpt.labels);
        assert_eq!(back.state.k(), 2);
        assert_eq!(back.state.alpha, 3.5);
        assert_eq!(back.state.clusters[0].count(), 3.0);
        assert_eq!(back.state.clusters[0].weight, 0.7);
        assert_eq!(back.state.clusters[1].age, 8);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_multinomial_checkpoint() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let prior = Prior::DirMult(crate::stats::DirMultPrior::symmetric(3, 0.5));
        let mut state = DpmmState::new(1.0, prior.clone(), 1, 2, &mut rng);
        state.clusters[0].stats.add(&[1.0, 2.0, 0.0]);
        state.clusters[0].stats.add(&[0.0, 1.0, 3.0]);
        let ckpt = Checkpoint { state, iter: 3, labels: vec![0, 0] };
        let p = std::env::temp_dir().join(format!("dpmm_ckpt_m_{}.bin", std::process::id()));
        ckpt.save(&p).unwrap();
        let back = Checkpoint::load(&p, &mut rng).unwrap();
        assert_eq!(back.state.clusters[0].count(), 2.0);
        match &back.state.clusters[0].stats {
            Stats::Mult(m) => assert_eq!(m.sum_x, vec![1.0, 3.0, 3.0]),
            _ => panic!("wrong stats family"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_corrupt_checkpoints() {
        let p = std::env::temp_dir().join(format!("dpmm_ckpt_bad_{}.bin", std::process::id()));
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        assert!(Checkpoint::load(&p, &mut rng).is_err());
        // Truncated real checkpoint.
        let ckpt = Checkpoint { state: make_state(), iter: 1, labels: vec![0; 6] };
        ckpt.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p, &mut rng).is_err());
        std::fs::remove_file(&p).ok();
    }
}
