//! The iteration driver: wires the sampler (weights/params/splits/merges) to
//! a [`Backend`] (labels/statistics) and runs the MCMC schedule — the
//! `group_step()` loop of the paper's §4.1, backend-agnostic.

pub mod checkpoint;

pub use checkpoint::Checkpoint;

use crate::backend::distributed::{DistributedBackend, DistributedConfig};
use crate::backend::native::{NativeBackend, NativeConfig};
use crate::backend::xla::{KernelChoice, XlaBackend, XlaConfig};
use crate::backend::Backend;
use crate::config::{BackendChoice, DpmmParams};
use crate::datagen::Data;
use crate::model::DpmmState;
use crate::rng::{Rng, Xoshiro256pp};
use crate::sampler::{
    age_clusters, apply_merge, apply_split, propose_merges, propose_splits, sample_params,
    sample_sub_weights, sample_weights, StepParams,
};
use crate::stats::Params;
use crate::util::json::Json;
use crate::util::timer::PhaseTimer;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

/// Per-iteration diagnostics (the paper's result file reports running time
/// per iteration; we add K and the joint-posterior proxy).
#[derive(Debug, Clone)]
pub struct IterRecord {
    pub iter: usize,
    pub k: usize,
    pub log_posterior: f64,
    pub seconds: f64,
    pub splits: usize,
    pub merges: usize,
}

/// Final output of a fit.
#[derive(Debug)]
pub struct FitResult {
    pub labels: Vec<usize>,
    pub weights: Vec<f64>,
    /// Posterior-mean component parameters (one per surviving cluster).
    pub params: Vec<Params>,
    pub history: Vec<IterRecord>,
    pub timer: PhaseTimer,
    pub backend_name: &'static str,
}

impl FitResult {
    pub fn num_clusters(&self) -> usize {
        self.weights.len()
    }

    pub fn total_seconds(&self) -> f64 {
        self.history.iter().map(|r| r.seconds).sum()
    }

    /// Paper-style result JSON: labels, weights, per-iteration times
    /// (+ NMI when ground truth is supplied).
    pub fn to_json(&self, truth: Option<&[usize]>) -> Json {
        let mut fields = vec![
            ("backend", Json::from(self.backend_name)),
            ("num_clusters", Json::from(self.num_clusters())),
            ("weights", Json::arr_f64(&self.weights)),
            ("labels", Json::arr_usize(&self.labels)),
            (
                "iter_seconds",
                Json::Arr(self.history.iter().map(|r| Json::Num(r.seconds)).collect()),
            ),
            (
                "iter_k",
                Json::Arr(self.history.iter().map(|r| Json::Num(r.k as f64)).collect()),
            ),
            ("total_seconds", Json::Num(self.total_seconds())),
        ];
        if let Some(t) = truth {
            fields.push(("nmi", Json::Num(crate::metrics::nmi(t, &self.labels))));
            fields.push(("ari", Json::Num(crate::metrics::ari(t, &self.labels))));
        }
        Json::obj(fields)
    }
}

/// Builder-style front door (the single entry point the paper's Python
/// wrapper provides; here it is the Rust API and the CLI both).
#[derive(Debug, Clone)]
pub struct DpmmFit {
    params: DpmmParams,
}

impl DpmmFit {
    pub fn new(params: DpmmParams) -> Self {
        Self { params }
    }

    pub fn iterations(mut self, n: usize) -> Self {
        self.params.iterations = n;
        self
    }

    pub fn alpha(mut self, alpha: f64) -> Self {
        self.params.alpha = alpha;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.params.seed = seed;
        self
    }

    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.params.backend = backend;
        self
    }

    pub fn verbose(mut self, v: bool) -> Self {
        self.params.verbose = v;
        self
    }

    pub fn burnout(mut self, b: usize) -> Self {
        self.params.burnout = b;
        self
    }

    pub fn max_clusters(mut self, k: usize) -> Self {
        self.params.max_clusters = k;
        self
    }

    pub fn params(&self) -> &DpmmParams {
        &self.params
    }

    /// Construct the configured backend for `data`.
    pub fn build_backend(&self, data: Arc<Data>, rng: &mut impl Rng) -> Result<Box<dyn Backend>> {
        let prior = self.params.prior.build();
        if prior.dim() != data.d {
            bail!("prior dimension {} does not match data dimension {}", prior.dim(), data.d);
        }
        Ok(match &self.params.backend {
            BackendChoice::Native { threads, shard_size } => {
                let config = NativeConfig {
                    threads: if *threads == 0 {
                        crate::util::threadpool::default_threads()
                    } else {
                        *threads
                    },
                    shard_size: (*shard_size).max(1),
                    ..NativeConfig::default()
                };
                Box::new(NativeBackend::new(data, prior, config, rng))
            }
            BackendChoice::Xla { artifact_dir, shard_size, kernel, crossover } => {
                let kernel = match kernel.as_str() {
                    "direct" => KernelChoice::Direct,
                    "matmul" => KernelChoice::Matmul,
                    _ => KernelChoice::Auto { crossover: *crossover },
                };
                let config = XlaConfig {
                    artifact_dir: artifact_dir.into(),
                    shard_size: (*shard_size).max(1),
                    kernel,
                };
                Box::new(XlaBackend::new(data, prior, config, rng)?)
            }
            BackendChoice::Distributed { workers, worker_threads } => {
                let config = DistributedConfig {
                    workers: workers.clone(),
                    worker_threads: (*worker_threads).max(1),
                };
                Box::new(DistributedBackend::new(data, prior, config, rng)?)
            }
        })
    }

    /// Fit on `data` with the configured backend.
    pub fn fit(&self, data: &Data) -> Result<FitResult> {
        let data = Arc::new(data.clone());
        let mut rng = Xoshiro256pp::seed_from_u64(self.params.seed);
        let mut backend = self.build_backend(Arc::clone(&data), &mut rng)?;
        self.fit_with_backend(data.n, backend.as_mut(), &mut rng)
    }

    /// Resume a fit from a checkpoint (native/xla backends; the distributed
    /// backend cannot restore labels over the wire and reports so).
    pub fn resume(&self, data: &Data, ckpt: Checkpoint) -> Result<FitResult> {
        let data = Arc::new(data.clone());
        let mut rng =
            Xoshiro256pp::seed_from_u64(self.params.seed.wrapping_add(ckpt.iter as u64));
        let mut backend = self.build_backend(Arc::clone(&data), &mut rng)?;
        backend.set_labels(&ckpt.labels)?;
        self.run_loop(ckpt.state, ckpt.iter, backend.as_mut(), &mut rng)
    }

    /// Fit using an externally constructed backend (tests, benches, reuse).
    pub fn fit_with_backend(
        &self,
        n_total: usize,
        backend: &mut dyn Backend,
        rng: &mut impl Rng,
    ) -> Result<FitResult> {
        let p = &self.params;
        let prior = p.prior.build();
        let state =
            DpmmState::new(p.alpha, prior.clone(), p.initial_clusters.max(1), n_total, rng);
        self.run_loop(state, 0, backend, rng)
    }

    fn run_loop(
        &self,
        mut state: DpmmState,
        start_iter: usize,
        backend: &mut dyn Backend,
        rng: &mut impl Rng,
    ) -> Result<FitResult> {
        let p = &self.params;
        let prior = p.prior.build();
        let opts = p.sampler_options();
        let mut timer = PhaseTimer::new();
        let mut history = Vec::with_capacity(p.iterations.saturating_sub(start_iter));
        for iter in start_iter..p.iterations {
            let t0 = Instant::now();
            // Steps (a)-(d): weights + parameters from current statistics.
            timer.time("params", || {
                sample_weights(&mut state, rng);
                sample_sub_weights(&mut state, rng);
                sample_params(&mut state, &opts, rng);
            });
            // Steps (e)/(f) + statistics on the backend.
            let snapshot = StepParams::snapshot(&state);
            let bundle = timer.time("assign", || backend.step(&snapshot))?;
            state.set_stats(bundle.cluster_stats(), bundle.sub_stats);
            // Drop empty clusters (keep at least one).
            timer.time("housekeeping", || -> Result<()> {
                let mut empties = state.empty_clusters();
                if empties.len() == state.k() && !empties.is_empty() {
                    empties.pop();
                }
                if !empties.is_empty() {
                    let map = state.remove_clusters(&empties);
                    backend.remap(&map)?;
                }
                Ok(())
            })?;
            age_clusters(&mut state);
            // Split/merge moves (suppressed during the final polish phase).
            let polish = iter + p.final_polish_iters >= p.iterations;
            let (mut n_splits, mut n_merges) = (0, 0);
            if !polish {
                timer.time("splitmerge", || -> Result<()> {
                    let split_targets = propose_splits(&state, &opts, rng);
                    if !split_targets.is_empty() {
                        let ops: Vec<_> = split_targets
                            .iter()
                            .map(|&t| apply_split(&mut state, t, rng))
                            .collect();
                        backend.apply_splits(&ops)?;
                        n_splits = ops.len();
                    }
                    let merge_ops = propose_merges(&state, &opts, rng);
                    if !merge_ops.is_empty() {
                        let mut absorbed = Vec::new();
                        for op in &merge_ops {
                            apply_merge(&mut state, op.keep, op.absorb, rng);
                            absorbed.push(op.absorb);
                        }
                        backend.apply_merges(&merge_ops)?;
                        let map = state.remove_clusters(&absorbed);
                        backend.remap(&map)?;
                        n_merges = merge_ops.len();
                    }
                    Ok(())
                })?;
            }
            let record = IterRecord {
                iter,
                k: state.k(),
                log_posterior: state.log_posterior_proxy(),
                seconds: t0.elapsed().as_secs_f64(),
                splits: n_splits,
                merges: n_merges,
            };
            if p.verbose {
                eprintln!(
                    "iter {:>4}  K={:<3} logp={:>14.2} splits={} merges={}  {:.3}s",
                    record.iter, record.k, record.log_posterior, record.splits, record.merges,
                    record.seconds
                );
            }
            history.push(record);
            // Crash-recovery checkpoint (the paper's JLD2 save/restore role).
            if let Some(path) = &p.checkpoint_path {
                if p.checkpoint_every > 0 && (iter + 1) % p.checkpoint_every == 0 {
                    let labels =
                        backend.labels()?.into_iter().map(|l| l as u32).collect();
                    let ckpt =
                        Checkpoint { state: state.clone(), iter: iter + 1, labels };
                    if let Err(e) = ckpt.save(path) {
                        eprintln!("warning: checkpoint save failed: {e}");
                    }
                }
            }
        }
        let labels = backend.labels()?;
        let weights = {
            let total: f64 = state.counts().iter().sum();
            state.counts().iter().map(|&c| c / total.max(1.0)).collect()
        };
        let params =
            state.clusters.iter().map(|c| prior.mean_params(&c.stats)).collect::<Vec<_>>();
        Ok(FitResult {
            labels,
            weights,
            params,
            history,
            timer,
            backend_name: backend.name(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GmmSpec;
    use crate::metrics::nmi;

    fn fit_gmm(n: usize, d: usize, k: usize, seed: u64, iters: usize) -> (FitResult, Vec<usize>) {
        let mut gen_rng = Xoshiro256pp::seed_from_u64(seed);
        let ds = GmmSpec::default_with(n, d, k).generate(&mut gen_rng);
        let mut params = DpmmParams::gaussian_default(d);
        params.iterations = iters;
        params.seed = seed + 1;
        params.backend = BackendChoice::Native { threads: 4, shard_size: 2048 };
        let fit = DpmmFit::new(params).fit(&ds.points).unwrap();
        (fit, ds.labels)
    }

    #[test]
    fn recovers_three_gaussians() {
        let (fit, truth) = fit_gmm(3000, 2, 3, 42, 60);
        let score = nmi(&truth, &fit.labels);
        assert!(score > 0.9, "NMI too low: {score} (K={})", fit.num_clusters());
        assert!(
            (2..=5).contains(&fit.num_clusters()),
            "K={} should be near 3",
            fit.num_clusters()
        );
    }

    #[test]
    fn recovers_more_clusters_higher_dim() {
        let (fit, truth) = fit_gmm(4000, 8, 6, 7, 80);
        let score = nmi(&truth, &fit.labels);
        assert!(score > 0.85, "NMI too low: {score} (K={})", fit.num_clusters());
    }

    #[test]
    fn history_is_complete_and_times_positive() {
        let (fit, _) = fit_gmm(500, 2, 2, 3, 20);
        assert_eq!(fit.history.len(), 20);
        assert!(fit.history.iter().all(|r| r.seconds > 0.0));
        assert!(fit.total_seconds() > 0.0);
        assert_eq!(fit.backend_name, "native");
        // K grows from 1 via splits.
        assert!(fit.history.last().unwrap().k >= 1);
    }

    #[test]
    fn fit_deterministic_given_seed() {
        let (a, _) = fit_gmm(800, 2, 3, 11, 30);
        let (b, _) = fit_gmm(800, 2, 3, 11, 30);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.num_clusters(), b.num_clusters());
    }

    #[test]
    fn multinomial_fit_works() {
        use crate::datagen::MultinomialSpec;
        let mut gen_rng = Xoshiro256pp::seed_from_u64(5);
        let ds = MultinomialSpec::default_with(2000, 16, 4).generate(&mut gen_rng);
        let mut params = DpmmParams::multinomial_default(16);
        params.iterations = 60;
        params.seed = 9;
        params.backend = BackendChoice::Native { threads: 4, shard_size: 1024 };
        let fit = DpmmFit::new(params).fit(&ds.points).unwrap();
        let score = nmi(&ds.labels, &fit.labels);
        assert!(score > 0.75, "NMI too low: {score} (K={})", fit.num_clusters());
    }

    #[test]
    fn result_json_has_expected_fields() {
        let (fit, truth) = fit_gmm(300, 2, 2, 1, 15);
        let j = fit.to_json(Some(&truth));
        assert!(j.get("nmi").is_some());
        assert!(j.get("weights").is_some());
        assert_eq!(
            j.get("labels").unwrap().as_arr().unwrap().len(),
            300
        );
        let s = crate::util::json::to_string(&j);
        assert!(crate::util::json::parse(&s).is_ok());
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let mut gen_rng = Xoshiro256pp::seed_from_u64(0);
        let ds = GmmSpec::default_with(100, 3, 2).generate(&mut gen_rng);
        let params = DpmmParams::gaussian_default(2); // wrong d
        assert!(DpmmFit::new(params).fit(&ds.points).is_err());
    }

    #[test]
    fn weights_sum_to_one() {
        let (fit, _) = fit_gmm(600, 2, 3, 21, 25);
        let total: f64 = fit.weights.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
