//! Incremental fitting: fold mini-batches into an existing [`DpmmState`]
//! without a full refit.
//!
//! Per ingested batch the fitter runs four deterministic phases:
//!
//! 1. **Decay** (optional): the frozen evidence base is scaled by
//!    `decay` (exponential forgetting, [`crate::stats::Stats::decay`]), so
//!    drifting streams track the present instead of averaging history.
//! 2. **MAP seeding**: new points get labels from the serving engine's MAP
//!    assignment — posterior-mean [`crate::sampler::KernelDesc`] scores with
//!    count-proportional weights ([`StepPlan::map_from_state`]), argmaxed.
//!    No RNG, so seeding is identical across thread counts and kernels.
//! 3. **Grouped fold**: the batch enters the window's sufficient-statistics
//!    contribution through the tiled `add_cols` path; points scrolling out
//!    of the window are retired into the frozen base with `remove_cols` /
//!    `add_cols` (their evidence stays in the model; only their labels
//!    freeze).
//! 4. **Restricted sweeps**: `sweeps` restricted-Gibbs passes over the
//!    sliding window, reusing the fit path's shard kernels
//!    ([`crate::backend::shard`]) verbatim — K stays fixed (no split/merge
//!    moves), only recent labels move.
//!
//! # Determinism contract
//!
//! A fixed-seed ingest history (same batches, same batch boundaries) yields
//! **bitwise-identical** labels and statistics regardless of the thread
//! count and of the assignment kernel (tiled vs scalar). Three properties
//! make that hold, and `tests/prop_kernel_equiv.rs` pins them:
//!
//! * the window shards into fixed-size chunks with per-shard forked RNGs in
//!   shard order (thread scheduling never touches an RNG stream),
//! * tiled and scalar kernels draw identical uniforms and produce identical
//!   labels under the same plan (the PR-1 oracle contract),
//! * statistics are **never** taken from the kernels' bundles (those differ
//!   between kernels in final ulps); they are maintained by a canonical
//!   single-threaded grouped fold that depends only on point values and
//!   label sequences — so identical labels induce identical plans for the
//!   next sweep, closing the induction.

use super::buffer::StreamBuffer;
use super::checkpoint::{
    load_stream_checkpoint, save_stream_checkpoint, StreamCheckpointCfg, StreamSave,
    WindowContents,
};
use crate::backend::executor::executor_for;
use crate::backend::shard::{map_shards_mut, AssignKernel, Shard, DEFAULT_TILE};
use crate::datagen::Data;
use crate::model::{Cluster, DpmmState, LEFT, RIGHT};
use crate::rng::{Rng, Xoshiro256pp};
use crate::sampler::{
    sample_params, sample_sub_weights, sample_weights, SamplerOptions, ScoreGraph, StepParams,
    StepPlan,
};
use crate::serve::ModelSnapshot;
use crate::stats::Stats;
use crate::util::threadpool::{default_threads, parallel_map};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Fixed tile width of the canonical statistics fold. Deliberately **not**
/// configurable: the fold's FP reduction order is part of the determinism
/// contract, so it must not vary with tuning knobs.
const FOLD_TILE: usize = 128;

/// Liveness/degradation summary of a stream fitter's execution substrate,
/// surfaced through the serving `/stats` endpoint (serve protocol v4).
/// Local fitters report zero workers and are never degraded; the
/// distributed leader reports its worker fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamHealth {
    /// Worker slots in the session (live + failed; gracefully removed
    /// workers are excluded).
    pub workers_total: u32,
    /// Workers currently reachable.
    pub workers_alive: u32,
    /// Live workers the supervisor's heartbeat registry currently rates
    /// `Healthy` (fit-wire v4 `Ping`/`Pong`). With supervision disabled
    /// every live worker counts as healthy.
    pub workers_healthy: u32,
    /// Live workers rated `Suspect`: probes failing, but still inside the
    /// eviction grace period. Always 0 with supervision disabled.
    pub workers_suspect: u32,
    /// Workers rated `Dead` or already failed/evicted this session.
    pub workers_dead: u32,
    /// A worker failed this session and its batches were re-sharded onto
    /// survivors (latches until restart/resume — the failure stays
    /// visible even after capacity recovers via joins).
    pub degraded: bool,
    /// Ingest is halted (unrecoverable: no live workers, or a fold
    /// invariant broke); predictions keep serving the last snapshot.
    pub halted: bool,
}

impl StreamHealth {
    /// Health of a single-process fitter: no workers, never degraded.
    pub fn local() -> StreamHealth {
        StreamHealth {
            workers_total: 0,
            workers_alive: 0,
            workers_healthy: 0,
            workers_suspect: 0,
            workers_dead: 0,
            degraded: false,
            halted: false,
        }
    }
}

/// Backend-generic streaming fitter surface, driven by the serving
/// batcher: the local in-process [`IncrementalFitter`] and the distributed
/// leader ([`crate::stream::DistributedFitter`]) implement the same
/// contract, so [`crate::serve::spawn_streaming`] hot-swaps re-planned
/// snapshots from either without knowing where the sweeps ran.
pub trait StreamFitter: Send {
    /// Model dimensionality (must match the serving engine's).
    fn dim(&self) -> usize;
    /// Cluster count (fixed across ingests — streaming never splits or
    /// merges).
    fn k(&self) -> usize;
    /// Fold one row-major mini-batch (`batch.len() / dim()` points) into
    /// the model.
    fn ingest(&mut self, batch: &[f64]) -> Result<IngestSummary>;
    /// Freeze the current model into a serving snapshot (what the hot-swap
    /// path re-plans after every applied ingest group).
    fn snapshot(&self) -> Result<ModelSnapshot>;
    /// Points ingested over the fitter's lifetime.
    fn ingested(&self) -> u64;
    /// Execution-substrate health (worker fleet state in distributed
    /// mode), mirrored into the serving `/stats` reply.
    fn health(&self) -> StreamHealth {
        StreamHealth::local()
    }
    /// Idle-time maintenance hook, called by the serving batcher between
    /// ingest groups: the distributed leader acts on supervisor verdicts
    /// here (proactive eviction + re-shard) so a dead worker is handled
    /// even when no ingest is in flight. No-op for local fitters.
    fn tick(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Streaming/incremental-fitting knobs.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Sliding-window capacity in points (older points freeze into the
    /// evidence base and stop being resweepable).
    pub window: usize,
    /// Restricted-Gibbs sweeps over the window per ingested batch.
    pub sweeps: usize,
    /// Exponential forgetting factor applied to the frozen base per ingest
    /// (1.0 = no forgetting; < 1.0 tracks drift).
    pub decay: f64,
    /// Worker threads for the window sweep (0 = core count / `DPMM_THREADS`).
    pub threads: usize,
    /// Window shard granularity — the unit of thread-invariant parallelism.
    pub shard_size: usize,
    /// Assignment-kernel tile width.
    pub tile: usize,
    /// Assignment kernel (tiled production kernel, the scalar oracle, or
    /// the device-emulation executor).
    pub kernel: AssignKernel,
    /// DP concentration for the restricted sweeps (snapshots don't carry α).
    pub alpha: f64,
    /// RNG seed for the sweep streams.
    pub seed: u64,
    /// Periodic streaming-state checkpointing (`None` = only explicit
    /// [`IncrementalFitter::save_stream_checkpoint`] calls).
    pub checkpoint: Option<StreamCheckpointCfg>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        Self {
            window: 32 * 1024,
            sweeps: 2,
            decay: 1.0,
            threads: 0,
            shard_size: 8 * 1024,
            tile: DEFAULT_TILE,
            kernel: AssignKernel::from_env(),
            alpha: 10.0,
            seed: 0,
            checkpoint: None,
        }
    }
}

/// What one [`IncrementalFitter::ingest`] call did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IngestSummary {
    /// Points accepted from this batch.
    pub accepted: usize,
    /// Windowed points after the ingest.
    pub window: usize,
    /// Points retired into the frozen base by this ingest.
    pub evicted: usize,
    /// Cluster count (fixed across ingests — no split/merge moves).
    pub k: usize,
}

/// Streaming incremental fitter over a sliding window.
///
/// ```no_run
/// use dpmm::serve::ModelSnapshot;
/// use dpmm::stream::{IncrementalFitter, StreamConfig};
///
/// let snapshot = ModelSnapshot::load("model.snap")?;
/// let mut fitter = IncrementalFitter::from_snapshot(
///     &snapshot,
///     StreamConfig { window: 65_536, sweeps: 2, ..StreamConfig::default() },
/// )?;
/// let summary = fitter.ingest(&[0.5, -0.25, 1.0, 2.0])?; // two 2-d points
/// println!("window now holds {} points", summary.window);
/// fitter.save_stream_checkpoint("stream.ckpt")?; // durable, resumable
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct IncrementalFitter {
    state: DpmmState,
    /// Frozen evidence per (cluster, sub-cluster): everything that ever
    /// scrolled out of the window, plus the seed snapshot's statistics
    /// (split half/half across the sub-sides to keep step (c)/(d) sampled).
    base: Vec<[Stats; 2]>,
    /// The window's live contribution per (cluster, sub-cluster); maintained
    /// by the canonical grouped fold, never by the sweep kernels.
    win: Vec<[Stats; 2]>,
    buffer: StreamBuffer,
    rng: Xoshiro256pp,
    cfg: StreamConfig,
    ingested: u64,
    batches_since_ckpt: usize,
}

impl IncrementalFitter {
    /// Seed from a frozen model export (`DPMMSNAP` file or
    /// [`ModelSnapshot::from_checkpoint_file`]). The snapshot's statistics
    /// become the initial evidence base; the window starts empty.
    pub fn from_snapshot(snap: &ModelSnapshot, cfg: StreamConfig) -> Result<IncrementalFitter> {
        if !(cfg.decay > 0.0 && cfg.decay <= 1.0) {
            bail!("stream decay must be in (0, 1], got {}", cfg.decay);
        }
        if !(cfg.alpha > 0.0) {
            bail!("stream alpha must be positive, got {}", cfg.alpha);
        }
        let (state, base) = seed_state_from_snapshot(snap, cfg.alpha)?;
        let k = state.k();
        let prior = state.prior.clone();
        let d = prior.dim();
        let win = prior.empty_bundle(k);
        Ok(IncrementalFitter {
            state,
            base,
            win,
            buffer: StreamBuffer::new(d, cfg.window.max(1)),
            rng: Xoshiro256pp::seed_from_u64(cfg.seed),
            cfg,
            ingested: 0,
            batches_since_ckpt: 0,
        })
    }

    /// Resume from a streaming checkpoint written by
    /// [`Self::save_stream_checkpoint`]: model, accumulators, RNG lineage,
    /// and the full window (values + labels) are restored exactly, so a
    /// resumed fixed-seed ingest history is **bitwise-identical** to the
    /// uninterrupted one. `window`/`sweeps`/`decay`/`alpha` come from the
    /// checkpoint (the determinism contract requires them unchanged);
    /// execution knobs (threads, shard size, tile, kernel) come from `cfg`
    /// — they never affect results, only speed.
    pub fn resume(path: impl AsRef<Path>, cfg: StreamConfig) -> Result<IncrementalFitter> {
        let ck = load_stream_checkpoint(&path)?;
        let WindowContents::Local { values, z, zsub } = ck.contents else {
            bail!(
                "checkpoint {} holds a distributed window — resume it with --workers",
                path.as_ref().display()
            );
        };
        let mut state = ck.state();
        sync_model_stats(&mut state, &ck.base, &ck.win);
        let d = state.prior.dim();
        let mut buffer = StreamBuffer::new(d, ck.window.max(1));
        buffer.push(&values, &z, &zsub);
        Ok(IncrementalFitter {
            state,
            base: ck.base,
            win: ck.win,
            buffer,
            rng: Xoshiro256pp::from_state(ck.rng),
            cfg: StreamConfig {
                window: ck.window,
                sweeps: ck.sweeps,
                decay: ck.decay,
                alpha: ck.alpha,
                ..cfg
            },
            ingested: ck.ingested,
            batches_since_ckpt: 0,
        })
    }

    /// Write a durable streaming checkpoint (atomic temp-file + rename):
    /// model, `base`/`win` accumulators, RNG lineage, and the full window
    /// contents. [`Self::resume`] replays it bitwise-identically.
    pub fn save_stream_checkpoint(&self, path: impl AsRef<Path>) -> Result<()> {
        save_stream_checkpoint(
            path,
            &StreamSave {
                state: &self.state,
                rng: self.rng.state(),
                ingested: self.ingested,
                next_batch_id: 0,
                window: self.cfg.window,
                sweeps: self.cfg.sweeps,
                decay: self.cfg.decay,
                alpha: self.cfg.alpha,
                base: &self.base,
                win: &self.win,
                contents: WindowContents::Local {
                    values: self.buffer.values().to_vec(),
                    z: self.buffer.labels().to_vec(),
                    zsub: self.buffer.sub_labels().to_vec(),
                },
            },
        )
        .with_context(|| "writing streaming checkpoint".to_string())
    }

    pub fn k(&self) -> usize {
        self.state.k()
    }

    pub fn dim(&self) -> usize {
        self.state.prior.dim()
    }

    /// Points ingested over the fitter's lifetime.
    pub fn ingested(&self) -> u64 {
        self.ingested
    }

    /// Points currently in the resweepable window.
    pub fn window_len(&self) -> usize {
        self.buffer.len()
    }

    /// Current labels of the windowed points (ingest order, oldest first).
    pub fn window_labels(&self) -> &[u32] {
        self.buffer.labels()
    }

    /// Current sub-labels of the windowed points.
    pub fn window_sub_labels(&self) -> &[u8] {
        self.buffer.sub_labels()
    }

    /// Per-cluster point masses (base + window evidence).
    pub fn counts(&self) -> Vec<f64> {
        self.state.counts()
    }

    pub fn state(&self) -> &DpmmState {
        &self.state
    }

    /// Freeze the current model into a serving snapshot (this is what the
    /// hot-swap path re-plans after every applied ingest).
    pub fn snapshot(&self) -> Result<ModelSnapshot> {
        ModelSnapshot::from_state(&self.state)
    }

    /// Fold one row-major mini-batch (`batch.len() / d` points) into the
    /// model: decay → MAP seed → grouped fold → window eviction →
    /// `cfg.sweeps` restricted sweeps. See the module docs.
    pub fn ingest(&mut self, batch: &[f64]) -> Result<IngestSummary> {
        let d = self.dim();
        if batch.len() % d != 0 {
            bail!(
                "ingest batch length {} is not a multiple of the model dimension {d}",
                batch.len()
            );
        }
        if batch.iter().any(|v| !v.is_finite()) {
            bail!("ingest batch contains non-finite values");
        }
        let n = batch.len() / d;
        if n == 0 {
            return Ok(IngestSummary {
                accepted: 0,
                window: self.buffer.len(),
                evicted: 0,
                k: self.k(),
            });
        }

        // 1. Exponential forgetting on the frozen base (the window's
        // contribution is recent by construction and keeps full weight
        // until it scrolls out).
        if self.cfg.decay < 1.0 {
            for b in self.base.iter_mut() {
                b[0].decay(self.cfg.decay);
                b[1].decay(self.cfg.decay);
            }
            // The seed plan below must see the decayed evidence — without
            // this resync a drifting cluster keeps its stale pre-decay
            // weight in the MAP argmax for one more ingest.
            self.sync_state();
        }

        // 2. Deterministic MAP seeding (no RNG — see module docs).
        let threads = self.threads();
        let plan = StepPlan::map_from_state(&self.state);
        let (z0, zsub0) = map_seed(&plan, batch, n, d, threads);

        // 3. Canonical grouped fold of the batch into the window stats.
        let all: Vec<u32> = (0..n as u32).collect();
        fold_groups(&mut self.win, batch, d, &all, &z0, &zsub0, true);
        self.buffer.push(batch, &z0, &zsub0);

        // 4. Retire overflow into the frozen base (labels freeze as-is).
        let evicted = self.buffer.overflow();
        if evicted > 0 {
            let sel: Vec<u32> = (0..evicted as u32).collect();
            let (vals, z, zsub) =
                (self.buffer.values(), self.buffer.labels(), self.buffer.sub_labels());
            fold_groups(&mut self.win, vals, d, &sel, z, zsub, false);
            fold_groups(&mut self.base, vals, d, &sel, z, zsub, true);
            self.buffer.evict_front(evicted);
        }
        self.sync_state();

        // 5. Restricted sweeps over the window.
        self.resweep(self.cfg.sweeps);

        self.ingested += n as u64;
        self.state.n_total += n;
        crate::telemetry::catalog::ingest_points_total().add(n as u64);

        // 6. Periodic durable checkpoint. Best-effort on this path: an
        // unwritable checkpoint must not kill a healthy stream (explicit
        // `save_stream_checkpoint` calls still error loudly).
        self.batches_since_ckpt += 1;
        if let Some(ck) = &self.cfg.checkpoint {
            if ck.every_batches > 0 && self.batches_since_ckpt >= ck.every_batches {
                self.batches_since_ckpt = 0;
                let path = ck.path.clone();
                if let Err(e) = self.save_stream_checkpoint(&path) {
                    eprintln!("dpmm stream: warning: periodic checkpoint failed: {e:#}");
                }
            }
        }

        Ok(IngestSummary {
            accepted: n,
            window: self.buffer.len(),
            evicted,
            k: self.k(),
        })
    }

    fn threads(&self) -> usize {
        if self.cfg.threads == 0 {
            default_threads()
        } else {
            self.cfg.threads
        }
    }

    /// Rebuild the state's cluster statistics as base + window (fixed merge
    /// order: part of the determinism contract).
    fn sync_state(&mut self) {
        sync_model_stats(&mut self.state, &self.base, &self.win);
    }

    /// `sweeps` restricted-Gibbs passes over the window: steps (a)–(d) on
    /// the coordinator state, then the shard assignment kernels over
    /// fixed-size window shards, then the canonical delta fold of every
    /// moved label.
    fn resweep(&mut self, sweeps: usize) {
        let wlen = self.buffer.len();
        if wlen == 0 || sweeps == 0 {
            return;
        }
        let d = self.dim();
        // Zero-copy hand-off: the window's contiguous row-major values move
        // into the sweep's `Data` and move back at the end (no O(window·d)
        // clone per ingest). No early return below may skip the restore.
        let data = Data::new(wlen, d, self.buffer.take_values());
        // Fixed shard structure with per-shard RNG streams forked in shard
        // order — thread scheduling never reaches an RNG.
        let mut shards: Vec<Shard> = data
            .shard_ranges(self.cfg.shard_size.max(1))
            .into_iter()
            .map(|range| {
                let mut s = Shard::new(range, self.rng.fork());
                s.z.copy_from_slice(&self.buffer.labels()[s.range.clone()]);
                s.zsub.copy_from_slice(&self.buffer.sub_labels()[s.range.clone()]);
                s
            })
            .collect();
        let threads = self.threads();
        let opts = SamplerOptions { sub_restart_every: 0, ..SamplerOptions::default() };
        for _ in 0..sweeps {
            crate::telemetry::catalog::sweeps_total().inc();
            crate::telemetry::catalog::assign_points_total().add(wlen as u64);
            sample_weights(&mut self.state, &mut self.rng);
            sample_sub_weights(&mut self.state, &mut self.rng);
            sample_params(&mut self.state, &opts, &mut self.rng);
            let plan = StepParams::snapshot(&self.state).plan();
            let prev_z: Vec<u32> = shards.iter().flat_map(|s| s.z.iter().copied()).collect();
            let prev_zsub: Vec<u8> =
                shards.iter().flat_map(|s| s.zsub.iter().copied()).collect();
            run_shards(
                &data,
                &mut shards,
                &plan,
                &self.state.prior,
                self.cfg.kernel,
                self.cfg.tile,
                threads,
            );
            let new_z: Vec<u32> = shards.iter().flat_map(|s| s.z.iter().copied()).collect();
            let new_zsub: Vec<u8> =
                shards.iter().flat_map(|s| s.zsub.iter().copied()).collect();
            // Canonical delta fold: only moved points touch the window
            // accumulators (remove at the old coordinates, add at the new).
            let changed: Vec<u32> = (0..wlen)
                .filter(|&i| prev_z[i] != new_z[i] || prev_zsub[i] != new_zsub[i])
                .map(|i| i as u32)
                .collect();
            if !changed.is_empty() {
                fold_groups(&mut self.win, &data.values, d, &changed, &prev_z, &prev_zsub, false);
                fold_groups(&mut self.win, &data.values, d, &changed, &new_z, &new_zsub, true);
                self.sync_state();
            }
        }
        let z: Vec<u32> = shards.iter().flat_map(|s| s.z.iter().copied()).collect();
        let zsub: Vec<u8> = shards.iter().flat_map(|s| s.zsub.iter().copied()).collect();
        self.buffer.set_labels(z, zsub);
        self.buffer.restore_values(data.values);
    }
}

impl StreamFitter for IncrementalFitter {
    // Inherent methods win name resolution, so these delegate, not recurse.
    fn dim(&self) -> usize {
        IncrementalFitter::dim(self)
    }
    fn k(&self) -> usize {
        IncrementalFitter::k(self)
    }
    fn ingest(&mut self, batch: &[f64]) -> Result<IngestSummary> {
        IncrementalFitter::ingest(self, batch)
    }
    fn snapshot(&self) -> Result<ModelSnapshot> {
        IncrementalFitter::snapshot(self)
    }
    fn ingested(&self) -> u64 {
        IncrementalFitter::ingested(self)
    }
}

/// Build the coordinator-side model state + halved frozen evidence base
/// from a serving snapshot — the shared seeding path of the local
/// [`IncrementalFitter`] and the distributed streaming leader, so both
/// start every fixed-seed history from bitwise-identical statistics.
pub(crate) fn seed_state_from_snapshot(
    snap: &ModelSnapshot,
    alpha: f64,
) -> Result<(DpmmState, Vec<[Stats; 2]>)> {
    let prior = snap.prior.clone();
    let mut clusters = Vec::with_capacity(snap.k());
    let mut base = Vec::with_capacity(snap.k());
    for c in &snap.clusters {
        // Halve the seed statistics into the two sub-sides (0.5× is an
        // exact FP scaling, so the halves sum back bitwise): the sub
        // split is only a seed for step (c)/(d) parameter draws — the
        // fitter never proposes splits, so it needs no real bipartition.
        let mut half = c.stats.clone();
        half.decay(0.5);
        let params = prior.try_mean_params(&c.stats)?;
        let sub_p = prior.try_mean_params(&half)?;
        clusters.push(Cluster {
            stats: c.stats.clone(),
            sub_stats: [half.clone(), half.clone()],
            params,
            sub_params: [sub_p.clone(), sub_p],
            weight: c.weight,
            sub_weights: [0.5, 0.5],
            age: 1,
            since_restart: 0,
        });
        base.push([half.clone(), half]);
    }
    let state = DpmmState {
        alpha,
        prior,
        clusters,
        n_total: snap.n_total as usize,
    };
    Ok((state, base))
}

/// Rebuild every cluster's statistics as base + window contribution, in a
/// fixed merge order (base, then window, left then right) — part of the
/// determinism contract shared by the local and distributed fitters.
pub(crate) fn sync_model_stats(
    state: &mut DpmmState,
    base: &[[Stats; 2]],
    win: &[[Stats; 2]],
) {
    for (k, c) in state.clusters.iter_mut().enumerate() {
        let mut sub_l = base[k][LEFT].clone();
        sub_l.merge(&win[k][LEFT]);
        let mut sub_r = base[k][RIGHT].clone();
        sub_r.merge(&win[k][RIGHT]);
        let mut stats = sub_l.clone();
        stats.merge(&sub_r);
        c.stats = stats;
        c.sub_stats = [sub_l, sub_r];
    }
}

/// Run the assignment sweep over every shard via the shared scoped pool
/// ([`map_shards_mut`]), lowering the plan to the kernel IR and executing
/// it through the pluggable [`crate::backend::executor`] seam. Kernel
/// stats bundles are discarded — the fitter's canonical fold owns
/// statistics (see module docs), which is also why every executor
/// (including device emulation) is interchangeable here.
pub(crate) fn run_shards(
    data: &Data,
    shards: &mut [Shard],
    plan: &StepPlan,
    prior: &crate::stats::Prior,
    kernel: AssignKernel,
    tile: usize,
    threads: usize,
) {
    let graph = ScoreGraph::lower(plan);
    let exec = executor_for(kernel, tile);
    map_shards_mut(shards, threads, |shard| {
        exec.execute(&graph, data, shard, prior);
    });
}

/// Deterministic MAP seeding of a batch: per-point argmax over the frozen
/// cluster descriptors, then over the winner's sub-descriptors. Pure
/// scalar scoring (kernel-independent) in fixed chunks (thread-invariant).
pub(crate) fn map_seed(
    plan: &StepPlan,
    batch: &[f64],
    n: usize,
    d: usize,
    threads: usize,
) -> (Vec<u32>, Vec<u8>) {
    const CHUNK: usize = 4096;
    let ranges: Vec<std::ops::Range<usize>> =
        (0..n).step_by(CHUNK).map(|s| s..(s + CHUNK).min(n)).collect();
    let parts = parallel_map(&ranges, threads, |_, range| {
        let mut z = Vec::with_capacity(range.len());
        let mut zsub = Vec::with_capacity(range.len());
        for p in range.clone() {
            let x = &batch[p * d..(p + 1) * d];
            let mut best = f64::NEG_INFINITY;
            let mut zi = 0usize;
            for (c, desc) in plan.clusters.iter().enumerate() {
                let s = desc.loglik(x);
                if s > best {
                    best = s;
                    zi = c;
                }
            }
            let l = plan.sub[zi][LEFT].loglik(x);
            let r = plan.sub[zi][RIGHT].loglik(x);
            z.push(zi as u32);
            zsub.push(u8::from(r > l));
        }
        (z, zsub)
    });
    let mut z = Vec::with_capacity(n);
    let mut zsub = Vec::with_capacity(n);
    for (pz, ps) in parts {
        z.extend(pz);
        zsub.extend(ps);
    }
    (z, zsub)
}

/// Canonical grouped fold: apply the selected points to the per-(cluster,
/// sub) accumulators via `add_cols` (`add = true`) or `remove_cols`. Tiles
/// of [`FOLD_TILE`], ascending selection order, ascending (cluster, sub)
/// group order — single-threaded and kernel-independent by design, so the
/// resulting bit patterns depend only on values and labels.
pub(crate) fn fold_groups(
    target: &mut [[Stats; 2]],
    values: &[f64],
    d: usize,
    sel: &[u32],
    z: &[u32],
    zsub: &[u8],
    add: bool,
) {
    if sel.is_empty() {
        return;
    }
    let k = target.len();
    let mut panel = vec![0.0; d * FOLD_TILE];
    let mut groups: Vec<[Vec<u32>; 2]> =
        (0..k).map(|_| [Vec::new(), Vec::new()]).collect();
    let mut start = 0;
    while start < sel.len() {
        let m = FOLD_TILE.min(sel.len() - start);
        // Gather the tile feature-major (row stride = m).
        for (t, &p) in sel[start..start + m].iter().enumerate() {
            let row = &values[p as usize * d..(p as usize + 1) * d];
            for (i, &v) in row.iter().enumerate() {
                panel[i * m + t] = v;
            }
        }
        for g in groups.iter_mut() {
            g[0].clear();
            g[1].clear();
        }
        for (t, &p) in sel[start..start + m].iter().enumerate() {
            groups[z[p as usize] as usize][zsub[p as usize] as usize].push(t as u32);
        }
        for (c, g) in groups.iter().enumerate() {
            for (h, gh) in g.iter().enumerate() {
                if gh.is_empty() {
                    continue;
                }
                if add {
                    target[c][h].add_cols(&panel, m, gh);
                } else {
                    target[c][h].remove_cols(&panel, m, gh);
                }
            }
        }
        start += m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{NiwPrior, Prior};

    /// A tiny two-blob snapshot to seed fitters from.
    fn seed_snapshot() -> ModelSnapshot {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let mut state = DpmmState::new(1.0, prior.clone(), 2, 200, &mut rng);
        for (k, center) in [(0usize, -6.0f64), (1, 6.0)] {
            let mut s = prior.empty_stats();
            for i in 0..100 {
                s.add(&[center + 0.03 * (i % 9) as f64, 0.05 * (i % 7) as f64 - 0.15]);
            }
            state.clusters[k].stats = s;
        }
        ModelSnapshot::from_state(&state).unwrap()
    }

    fn blob_batch(center: f64, n: usize, phase: usize) -> Vec<f64> {
        let mut v = Vec::with_capacity(n * 2);
        for i in 0..n {
            v.push(center + 0.04 * ((i + phase) % 11) as f64 - 0.2);
            v.push(0.03 * ((i * 3 + phase) % 5) as f64);
        }
        v
    }

    fn cfg() -> StreamConfig {
        StreamConfig {
            window: 64,
            sweeps: 2,
            threads: 2,
            shard_size: 16,
            kernel: AssignKernel::Tiled,
            alpha: 2.0,
            seed: 9,
            ..StreamConfig::default()
        }
    }

    #[test]
    fn ingest_assigns_to_nearest_blob_and_tracks_counts() {
        let snap = seed_snapshot();
        let mut f = IncrementalFitter::from_snapshot(&snap, cfg()).unwrap();
        let before = f.counts();
        f.ingest(&blob_batch(-6.0, 30, 0)).unwrap();
        let s = f.ingest(&blob_batch(6.0, 30, 1)).unwrap();
        assert_eq!(s.accepted, 30);
        assert_eq!(s.window, 60);
        assert_eq!(s.evicted, 0);
        let after = f.counts();
        assert!((after[0] - before[0] - 30.0).abs() < 1e-6, "{before:?} -> {after:?}");
        assert!((after[1] - before[1] - 30.0).abs() < 1e-6);
        // Window labels follow the blobs.
        let labels = f.window_labels();
        assert!(labels[..30].iter().all(|&l| l == 0), "{labels:?}");
        assert!(labels[30..].iter().all(|&l| l == 1));
        assert_eq!(f.ingested(), 60);
    }

    #[test]
    fn eviction_freezes_evidence_but_preserves_total_mass() {
        let snap = seed_snapshot();
        let mut f = IncrementalFitter::from_snapshot(&snap, cfg()).unwrap();
        for phase in 0..4 {
            f.ingest(&blob_batch(-6.0, 30, phase)).unwrap();
        }
        // window = 64 < 120 ingested: overflow retired into the base.
        assert_eq!(f.window_len(), 64);
        let total: f64 = f.counts().iter().sum();
        assert!((total - 200.0 - 120.0).abs() < 1e-6, "total mass {total}");
        // Model still snapshots cleanly after evictions.
        let snap2 = f.snapshot().unwrap();
        assert_eq!(snap2.k(), 2);
    }

    #[test]
    fn decay_shrinks_old_mass() {
        let snap = seed_snapshot();
        let mut f = IncrementalFitter::from_snapshot(
            &snap,
            StreamConfig { decay: 0.5, ..cfg() },
        )
        .unwrap();
        f.ingest(&blob_batch(-6.0, 10, 0)).unwrap();
        // Base was 100+100 → decayed to 50+50; window adds 10.
        let total: f64 = f.counts().iter().sum();
        assert!((total - 110.0).abs() < 1e-6, "total {total}");
    }

    #[test]
    fn rejects_bad_batches() {
        let snap = seed_snapshot();
        let mut f = IncrementalFitter::from_snapshot(&snap, cfg()).unwrap();
        assert!(f.ingest(&[1.0, 2.0, 3.0]).is_err()); // not a multiple of d
        assert!(f.ingest(&[f64::NAN, 0.0]).is_err());
        let s = f.ingest(&[]).unwrap();
        assert_eq!(s.accepted, 0);
        assert!(
            IncrementalFitter::from_snapshot(
                &snap,
                StreamConfig { decay: 0.0, ..cfg() }
            )
            .is_err()
        );
    }

    #[test]
    fn resume_replays_bitwise_identically() {
        let snap = seed_snapshot();
        let batches: Vec<Vec<f64>> = (0..6)
            .map(|p| blob_batch(if p % 2 == 0 { -6.0 } else { 6.0 }, 20 + p, p))
            .collect();
        // Uninterrupted run.
        let mut full = IncrementalFitter::from_snapshot(&snap, cfg()).unwrap();
        for b in &batches {
            full.ingest(b).unwrap();
        }
        // Interrupted run: checkpoint after 3 batches, resume, finish.
        let mut first = IncrementalFitter::from_snapshot(&snap, cfg()).unwrap();
        for b in &batches[..3] {
            first.ingest(b).unwrap();
        }
        let p = std::env::temp_dir()
            .join(format!("dpmm_fitter_resume_{}.ckpt", std::process::id()));
        first.save_stream_checkpoint(&p).unwrap();
        drop(first);
        let mut resumed = IncrementalFitter::resume(&p, cfg()).unwrap();
        for b in &batches[3..] {
            resumed.ingest(b).unwrap();
        }
        assert_eq!(resumed.ingested(), full.ingested());
        assert_eq!(resumed.window_len(), full.window_len());
        assert_eq!(resumed.window_labels(), full.window_labels());
        assert_eq!(resumed.window_sub_labels(), full.window_sub_labels());
        for (a, b) in resumed.state().clusters.iter().zip(&full.state().clusters) {
            assert_eq!(a.stats, b.stats, "cluster stats must be bitwise-identical");
            assert_eq!(a.sub_stats, b.sub_stats);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn window_stats_match_label_recompute() {
        // The delta-fold bookkeeping must agree with a from-scratch grouped
        // recompute of the window contribution.
        let snap = seed_snapshot();
        let mut f = IncrementalFitter::from_snapshot(&snap, cfg()).unwrap();
        for phase in 0..3 {
            f.ingest(&blob_batch(if phase % 2 == 0 { -6.0 } else { 6.0 }, 25, phase))
                .unwrap();
        }
        let d = f.dim();
        let prior = f.state().prior.clone();
        let mut fresh: Vec<[Stats; 2]> =
            (0..f.k()).map(|_| [prior.empty_stats(), prior.empty_stats()]).collect();
        let sel: Vec<u32> = (0..f.window_len() as u32).collect();
        fold_groups(
            &mut fresh,
            f.buffer.values(),
            d,
            &sel,
            f.window_labels(),
            f.window_sub_labels(),
            true,
        );
        for (k, (a, b)) in f.win.iter().zip(&fresh).enumerate() {
            for h in 0..2 {
                assert_eq!(a[h].count(), b[h].count(), "k={k} h={h}");
            }
        }
    }
}
