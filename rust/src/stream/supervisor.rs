//! Cluster supervision: heartbeat registry + structured recovery event log.
//!
//! Through PR 5 the distributed leader was purely *reactive*: a dead worker
//! was only discovered when sweep/ingest I/O against it failed (worst case
//! one full `DPMM_NET_TIMEOUT_SECS` later). This module adds the proactive
//! half of ROADMAP item 5:
//!
//! * [`Supervisor`] — a leader-side thread that probes every registered
//!   worker's control socket with the fit-wire v4 `Ping`/`Pong` verbs on a
//!   configurable interval, and tracks per-worker liveness through the
//!   `Healthy → Suspect → Dead` state machine: a failed probe makes a
//!   worker `Suspect`; once no probe has succeeded for the grace period it
//!   is `Dead`. The fitter polls verdicts between ingests (and from the
//!   serving batcher's `tick`) and runs the PR 5 eviction + re-shard
//!   machinery *before* any sweep trips over the corpse.
//! * [`EventLog`] — every recovery decision (retry, liveness transition,
//!   eviction, re-ingest, rebalance, halt) emits one timestamped JSON line
//!   to stderr or a file (`DPMM_EVENT_LOG=path`), and into a bounded
//!   in-memory ring that tests assert against without scraping stderr.
//!
//! Probes ride fresh short-lived connections (`connect → Ping → Pong →
//! close`): workers answer `Ping` in any session state and serve each
//! connection on its own thread, so heartbeats never queue behind a sweep.
//! Supervision is **off by default** (`heartbeat_ms = 0`) and never draws
//! from the model RNG, so enabling it cannot perturb a trajectory — see
//! docs/DETERMINISM.md.

use crate::backend::distributed::wire::{self, Message};
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

// ---------- structured event log ----------

/// Lines kept in the in-memory ring for test/debug inspection.
const RECENT_CAP: usize = 4096;

enum EventSink {
    Stderr,
    File(std::fs::File),
}

/// Structured recovery event log: one compact JSON object per line, with
/// millisecond UNIX timestamps. Shared (`Arc`) between the fitter, its
/// supervisor thread, and the retry layer's callbacks.
pub struct EventLog {
    sink: Mutex<EventSink>,
    recent: Mutex<VecDeque<String>>,
    /// Monotonic per-log sequence number: consumers (`dpmm events`) detect
    /// dropped or truncated lines by gaps in `seq`.
    seq: AtomicU64,
}

impl EventLog {
    /// Log to stderr (the default sink).
    pub fn to_stderr() -> EventLog {
        EventLog {
            sink: Mutex::new(EventSink::Stderr),
            recent: Mutex::new(VecDeque::new()),
            seq: AtomicU64::new(0),
        }
    }

    /// Log to a file, appending.
    pub fn to_file(path: &std::path::Path) -> Result<EventLog> {
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening event log {}", path.display()))?;
        Ok(EventLog {
            sink: Mutex::new(EventSink::File(file)),
            recent: Mutex::new(VecDeque::new()),
            seq: AtomicU64::new(0),
        })
    }

    /// Sink selected by `DPMM_EVENT_LOG` (a path; unset/empty = stderr).
    /// Falls back to stderr (with a warning) if the path can't be opened.
    pub fn from_env() -> Arc<EventLog> {
        match std::env::var("DPMM_EVENT_LOG") {
            Ok(path) if !path.is_empty() => match EventLog::to_file(std::path::Path::new(&path)) {
                Ok(log) => Arc::new(log),
                Err(e) => {
                    eprintln!("warning: {e:#}; event log falls back to stderr");
                    Arc::new(EventLog::to_stderr())
                }
            },
            _ => Arc::new(EventLog::to_stderr()),
        }
    }

    /// Emit one event line. `fields` are appended to the implicit
    /// `ts_ms`/`seq`/`event` triple; the line goes to the sink and the
    /// ring, and bumps `dpmm_events_total{event=...}`.
    pub fn emit(&self, event: &str, fields: Vec<(&str, Json)>) {
        crate::telemetry::catalog::events_total(event).inc();
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0);
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        let mut pairs = vec![
            ("ts_ms", Json::from(ts_ms)),
            ("seq", Json::from(seq as usize)),
            ("event", Json::from(event)),
        ];
        pairs.extend(fields);
        let line = json::to_string(&Json::obj(pairs));
        match &mut *self.sink.lock().unwrap() {
            EventSink::Stderr => eprintln!("{line}"),
            EventSink::File(f) => {
                let _ = writeln!(f, "{line}");
            }
        }
        let mut recent = self.recent.lock().unwrap();
        if recent.len() == RECENT_CAP {
            recent.pop_front();
        }
        recent.push_back(line);
    }

    /// The most recent event lines, oldest first (bounded ring).
    pub fn recent(&self) -> Vec<String> {
        self.recent.lock().unwrap().iter().cloned().collect()
    }
}

// ---------- liveness registry ----------

/// Per-worker liveness verdict of the heartbeat registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Liveness {
    /// Last probe succeeded.
    Healthy,
    /// Probes are failing, but the grace period has not yet elapsed since
    /// the last success — could be a blip.
    Suspect,
    /// No successful probe within the grace period: evict.
    Dead,
}

impl Liveness {
    pub fn as_str(self) -> &'static str {
        match self {
            Liveness::Healthy => "healthy",
            Liveness::Suspect => "suspect",
            Liveness::Dead => "dead",
        }
    }
}

/// Supervision knobs (leader side).
#[derive(Debug, Clone, Copy)]
pub struct SupervisorConfig {
    /// Probe round interval.
    pub interval_ms: u64,
    /// How long probes may fail (since the last success) before the worker
    /// is rated `Dead`.
    pub grace_ms: u64,
    /// Per-probe connect/IO timeout.
    pub probe_timeout_ms: u64,
}

impl SupervisorConfig {
    /// Derive a config from the two user-facing knobs: probes time out at
    /// the grace period (clamped to [50 ms, 1 s]) so one wedged worker
    /// can't stall a probe round for long.
    pub fn new(interval_ms: u64, grace_ms: u64) -> SupervisorConfig {
        SupervisorConfig {
            interval_ms: interval_ms.max(1),
            grace_ms,
            probe_timeout_ms: grace_ms.clamp(50, 1000),
        }
    }
}

/// One registered worker's probe state.
struct Probe {
    addr: String,
    /// `false` once the fitter evicted or gracefully removed the worker —
    /// the slot index stays valid but probing stops.
    enabled: bool,
    liveness: Liveness,
    last_ok: Instant,
    consecutive_failures: u32,
    /// Last `Pong` payload (window points / batches / verb counter).
    load: u64,
    depth: u64,
    generation: u64,
}

struct Registry {
    probes: Mutex<Vec<Probe>>,
    stop: AtomicBool,
    cfg: SupervisorConfig,
    events: Arc<EventLog>,
}

/// Leader-side heartbeat supervisor: one background thread probing every
/// enabled registry entry. Registration order is the fitter's worker-slot
/// order, so verdict indices map 1:1 onto slots.
pub struct Supervisor {
    shared: Arc<Registry>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// One `connect → Ping → Pong → close` probe.
fn probe_once(addr: &str, timeout: Duration) -> Result<(u64, u64, u64)> {
    use std::net::ToSocketAddrs;
    let sa = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .with_context(|| format!("no socket address for {addr}"))?;
    let mut s = TcpStream::connect_timeout(&sa, timeout)?;
    s.set_nodelay(true).ok();
    s.set_read_timeout(Some(timeout))?;
    s.set_write_timeout(Some(timeout))?;
    match wire::request(&mut s, &Message::Ping)? {
        Message::Pong { load, depth, generation } => Ok((load, depth, generation)),
        other => bail!("unexpected heartbeat reply {other:?}"),
    }
}

fn supervise_loop(reg: &Registry) {
    let timeout = Duration::from_millis(reg.cfg.probe_timeout_ms);
    let grace = Duration::from_millis(reg.cfg.grace_ms);
    loop {
        if reg.stop.load(Ordering::SeqCst) {
            return;
        }
        let targets: Vec<(usize, String)> = {
            let g = reg.probes.lock().unwrap();
            g.iter()
                .enumerate()
                .filter(|(_, p)| p.enabled)
                .map(|(i, p)| (i, p.addr.clone()))
                .collect()
        };
        for (idx, addr) in targets {
            if reg.stop.load(Ordering::SeqCst) {
                return;
            }
            let watch = crate::telemetry::Stopwatch::start();
            let res = probe_once(&addr, timeout);
            let rtt = watch.elapsed();
            let mut g = reg.probes.lock().unwrap();
            let p = &mut g[idx];
            if !p.enabled {
                continue; // evicted while we probed
            }
            let prev = p.liveness;
            match res {
                Ok((load, depth, generation)) => {
                    if let Some(rtt) = rtt {
                        crate::telemetry::catalog::heartbeat_rtt(&addr).observe_duration(rtt);
                    }
                    p.load = load;
                    p.depth = depth;
                    p.generation = generation;
                    p.last_ok = Instant::now();
                    p.consecutive_failures = 0;
                    p.liveness = Liveness::Healthy;
                }
                Err(e) => {
                    p.consecutive_failures += 1;
                    p.liveness = if p.last_ok.elapsed() >= grace {
                        Liveness::Dead
                    } else {
                        Liveness::Suspect
                    };
                    if p.liveness == Liveness::Dead && prev != Liveness::Dead {
                        // Detection latency: silence onset (≈ last successful
                        // probe) to the Dead verdict.
                        crate::telemetry::catalog::detection_seconds()
                            .observe(p.last_ok.elapsed().as_secs_f64());
                    }
                    if p.liveness != prev {
                        reg.events.emit(
                            "liveness",
                            vec![
                                ("worker", Json::from(idx)),
                                ("addr", Json::from(addr.as_str())),
                                ("from", Json::from(prev.as_str())),
                                ("to", Json::from(p.liveness.as_str())),
                                ("failures", Json::from(p.consecutive_failures as usize)),
                                ("error", Json::from(format!("{e:#}"))),
                            ],
                        );
                    }
                }
            }
            if p.liveness != prev && p.liveness == Liveness::Healthy {
                reg.events.emit(
                    "liveness",
                    vec![
                        ("worker", Json::from(idx)),
                        ("addr", Json::from(addr.as_str())),
                        ("from", Json::from(prev.as_str())),
                        ("to", Json::from("healthy")),
                    ],
                );
            }
        }
        {
            let g = reg.probes.lock().unwrap();
            let mut c = (0f64, 0f64, 0f64);
            for p in g.iter().filter(|p| p.enabled) {
                match p.liveness {
                    Liveness::Healthy => c.0 += 1.0,
                    Liveness::Suspect => c.1 += 1.0,
                    Liveness::Dead => c.2 += 1.0,
                }
            }
            crate::telemetry::catalog::worker_liveness("healthy").set(c.0);
            crate::telemetry::catalog::worker_liveness("suspect").set(c.1);
            crate::telemetry::catalog::worker_liveness("dead").set(c.2);
        }
        // Sleep the interval in small steps so stop/drop returns promptly.
        let mut left = reg.cfg.interval_ms;
        while left > 0 && !reg.stop.load(Ordering::SeqCst) {
            let step = left.min(10);
            std::thread::sleep(Duration::from_millis(step));
            left -= step;
        }
    }
}

impl Supervisor {
    /// Start supervising `addrs` (in worker-slot order).
    pub fn spawn(addrs: &[String], cfg: SupervisorConfig, events: Arc<EventLog>) -> Supervisor {
        let probes = addrs
            .iter()
            .map(|a| Probe {
                addr: a.clone(),
                enabled: true,
                liveness: Liveness::Healthy,
                last_ok: Instant::now(),
                consecutive_failures: 0,
                load: 0,
                depth: 0,
                generation: 0,
            })
            .collect();
        let shared = Arc::new(Registry {
            probes: Mutex::new(probes),
            stop: AtomicBool::new(false),
            cfg,
            events,
        });
        let reg = Arc::clone(&shared);
        let thread = std::thread::spawn(move || supervise_loop(&reg));
        Supervisor { shared, thread: Some(thread) }
    }

    /// Register a newly joined worker (must mirror the fitter's slot push).
    pub fn register(&self, addr: &str) {
        self.shared.probes.lock().unwrap().push(Probe {
            addr: addr.to_string(),
            enabled: true,
            liveness: Liveness::Healthy,
            last_ok: Instant::now(),
            consecutive_failures: 0,
            load: 0,
            depth: 0,
            generation: 0,
        });
    }

    /// Stop probing slot `idx` (evicted or gracefully removed). The index
    /// keeps its place so later registrations stay slot-aligned.
    pub fn retire(&self, idx: usize) {
        if let Some(p) = self.shared.probes.lock().unwrap().get_mut(idx) {
            p.enabled = false;
        }
    }

    /// Current verdicts for enabled probes, as `(slot index, liveness)`.
    pub fn verdicts(&self) -> Vec<(usize, Liveness)> {
        self.shared
            .probes
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, p)| p.enabled)
            .map(|(i, p)| (i, p.liveness))
            .collect()
    }

    /// `(healthy, suspect, dead)` counts over enabled probes.
    pub fn counts(&self) -> (u32, u32, u32) {
        let g = self.shared.probes.lock().unwrap();
        let mut c = (0u32, 0u32, 0u32);
        for p in g.iter().filter(|p| p.enabled) {
            match p.liveness {
                Liveness::Healthy => c.0 += 1,
                Liveness::Suspect => c.1 += 1,
                Liveness::Dead => c.2 += 1,
            }
        }
        c
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::distributed::fault::FaultProxy;
    use crate::backend::distributed::worker::spawn_local;

    #[test]
    fn event_log_ring_keeps_lines_in_order() {
        let log = EventLog::to_stderr();
        log.emit("retry", vec![("worker", Json::from(1usize))]);
        log.emit("evict", vec![("worker", Json::from(2usize))]);
        let lines = log.recent();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"event\":\"retry\"") && lines[0].contains("\"ts_ms\""));
        assert!(lines[1].contains("\"event\":\"evict\"") && lines[1].contains("\"worker\":2"));
        // Monotonic per-log sequence numbers for gap detection.
        assert!(lines[0].contains("\"seq\":0"), "{}", lines[0]);
        assert!(lines[1].contains("\"seq\":1"), "{}", lines[1]);
        // Every line is valid JSON.
        for l in &lines {
            json::parse(l).unwrap();
        }
    }

    #[test]
    fn event_log_file_sink_appends_lines() {
        let path = std::env::temp_dir().join(format!("dpmm_eventlog_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let log = EventLog::to_file(&path).unwrap();
        log.emit("halt", vec![("why", Json::from("test"))]);
        log.emit("join", vec![("addr", Json::from("x:1"))]);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().contains("\"event\":\"halt\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn heartbeat_rates_live_worker_healthy_and_silenced_worker_dead() {
        let proxy = FaultProxy::spawn(spawn_local().unwrap(), Vec::new()).unwrap();
        let events = Arc::new(EventLog::to_stderr());
        let sup = Supervisor::spawn(
            &[proxy.addr().to_string()],
            SupervisorConfig::new(25, 250),
            Arc::clone(&events),
        );
        let deadline = Instant::now() + Duration::from_secs(10);
        // Healthy while the proxy forwards…
        loop {
            let v = sup.verdicts();
            if v == vec![(0, Liveness::Healthy)] {
                break;
            }
            assert!(Instant::now() < deadline, "worker never rated healthy: {v:?}");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(sup.counts(), (1, 0, 0));
        // …Dead within the grace period once silenced.
        proxy.kill();
        let silenced = Instant::now();
        loop {
            if sup.verdicts() == vec![(0, Liveness::Dead)] {
                break;
            }
            assert!(Instant::now() < deadline, "worker never rated dead");
            std::thread::sleep(Duration::from_millis(10));
        }
        // Detection latency is bounded by grace + one probe round (+ slack).
        assert!(silenced.elapsed() < Duration::from_secs(5));
        assert_eq!(sup.counts(), (0, 0, 1));
        // The transition trail is in the event log.
        let lines = events.recent();
        assert!(lines.iter().any(|l| l.contains("\"to\":\"suspect\"")), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("\"to\":\"dead\"")), "{lines:?}");
        sup.retire(0);
        assert_eq!(sup.counts(), (0, 0, 0));
        assert!(sup.verdicts().is_empty());
    }
}
