//! Streaming ingestion + incremental fitting: absorb new data continuously
//! and refresh the serving model without a restart.
//!
//! The batch pipeline (coordinator + backends) fits once over a fixed data
//! matrix; the PR-2 serve layer then scores against that frozen fit. This
//! subsystem closes the loop for production streams:
//!
//! * [`StreamBuffer`] — a FIFO sliding window of the most recent points
//!   with their live labels (the only points whose assignments still move);
//! * [`IncrementalFitter`] — folds mini-batches into an existing
//!   [`crate::model::DpmmState`] through the grouped `add_cols` /
//!   `remove_cols` sufficient-statistics path, seeding labels from the
//!   serving engine's deterministic MAP assignment and then running
//!   `sweeps` restricted-Gibbs passes over the window (reusing the fit
//!   path's tiled/scalar shard kernels verbatim) instead of a full refit.
//!   Optional exponential forgetting ([`crate::stats::Stats::decay`])
//!   down-weights old evidence for drifting streams.
//!
//! Ingest is wired end-to-end: the serving wire protocol gains an `ingest`
//! verb ([`crate::serve::wire::ServeMessage::Ingest`]), `dpmm stream`
//! starts a serving endpoint whose micro-batcher applies queued ingests and
//! **hot-swaps** a freshly re-planned [`crate::serve::ModelSnapshot`]
//! between fused scoring passes (see [`crate::serve::server`] for the
//! consistency guarantees), and `python/dpmmwrapper.py`'s `DpmmClient`
//! speaks the same verb. `cargo bench --bench stream_ingest` quantifies
//! incremental ingest against a full refit at matched NMI
//! (`BENCH_stream.json`; EXPERIMENTS.md §Streaming has the protocol).
//!
//! The whole path is deterministic — see the contract in [`fitter`]'s docs,
//! pinned by `tests/prop_kernel_equiv.rs` and
//! `tests/prop_stats_roundtrip.rs`.

pub mod buffer;
pub mod fitter;

pub use buffer::StreamBuffer;
pub use fitter::{IncrementalFitter, IngestSummary, StreamConfig};
