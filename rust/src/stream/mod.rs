//! Streaming ingestion + incremental fitting: absorb new data continuously
//! and refresh the serving model without a restart — on one machine or
//! across an elastic, fault-tolerant TCP worker cluster.
//!
//! Components (the architecture map with data flow lives in
//! `docs/ARCHITECTURE.md`; the streaming determinism and fault-tolerance
//! contracts in `docs/DETERMINISM.md`):
//!
//! * [`StreamBuffer`] — FIFO sliding window of recent points + live labels;
//! * [`IncrementalFitter`] — single-machine streaming: MAP-seed, grouped
//!   statistics folds, restricted sweeps over the window, optional
//!   exponential forgetting;
//! * [`DistributedFitter`] — the same contract sharded across `dpmm
//!   worker` processes (`dpmm stream --workers=...`), with worker-failure
//!   recovery, elastic join/leave, and checkpointed leader durability;
//! * [`supervisor`] — proactive cluster supervision: a heartbeat registry
//!   rating each worker `Healthy → Suspect → Dead` (fit-wire v4
//!   `Ping`/`Pong`), plus the structured JSON [`EventLog`] every recovery
//!   decision is written to;
//! * [`checkpoint`] — the `DPMMCKPT` v3 streaming-state section both
//!   fitters save and `--resume` replays bitwise-identically.
//!
//! Both fitters implement [`StreamFitter`], the surface the serving
//! micro-batcher drives ([`crate::serve`] hot-swaps a re-planned
//! [`crate::serve::ModelSnapshot`] between fused scoring passes and
//! surfaces [`StreamHealth`] through `/stats`). The client-facing wire is
//! identical in local and cluster mode; both protocols are specified in
//! `docs/WIRE_PROTOCOLS.md`.
//!
//! Benchmarks: `stream_ingest` (incremental vs refit), `stream_distributed`
//! (worker scaling), `stream_recovery` (failure/recovery latency); see
//! EXPERIMENTS.md. Contracts are pinned by `tests/prop_kernel_equiv.rs`,
//! `tests/integration_stream_distributed.rs`, and
//! `tests/integration_stream_recovery.rs`.

pub mod buffer;
pub mod checkpoint;
pub mod distributed;
pub mod fitter;
pub mod supervisor;

pub use buffer::StreamBuffer;
pub use checkpoint::{load_stream_checkpoint, StreamCheckpoint, StreamCheckpointCfg};
pub use distributed::{DistributedFitter, DistributedStreamConfig};
pub use fitter::{IncrementalFitter, IngestSummary, StreamConfig, StreamFitter, StreamHealth};
pub use supervisor::{EventLog, Liveness, Supervisor, SupervisorConfig};
