//! Streaming ingestion + incremental fitting: absorb new data continuously
//! and refresh the serving model without a restart — on one machine or
//! across a TCP worker cluster.
//!
//! The batch pipeline (coordinator + backends) fits once over a fixed data
//! matrix; the PR-2 serve layer then scores against that frozen fit. This
//! subsystem closes the loop for production streams:
//!
//! * [`StreamBuffer`] — a FIFO sliding window of the most recent points
//!   with their live labels (the only points whose assignments still move);
//! * [`IncrementalFitter`] — the single-machine fitter: folds mini-batches
//!   into an existing [`crate::model::DpmmState`] through the grouped
//!   `add_cols` / `remove_cols` sufficient-statistics path, seeding labels
//!   from the serving engine's deterministic MAP assignment and then
//!   running `sweeps` restricted-Gibbs passes over the window (reusing the
//!   fit path's tiled/scalar shard kernels verbatim) instead of a full
//!   refit. Optional exponential forgetting
//!   ([`crate::stats::Stats::decay`]) down-weights old evidence for
//!   drifting streams.
//! * [`DistributedFitter`] — the same contract sharded across `dpmm
//!   worker` processes: the leader routes each mini-batch to the
//!   least-loaded worker's window slice, workers MAP-seed and resweep
//!   locally, and only O(K·d²) grouped statistics deltas return per sweep
//!   (see [`distributed`] for the design and the determinism argument).
//!   `dpmm stream --workers=host:port,...` turns one serving endpoint
//!   into a horizontally scalable ingest+serve cluster.
//!
//! Both fitters implement [`StreamFitter`], the surface the serving
//! micro-batcher drives: it applies queued ingests and **hot-swaps** a
//! freshly re-planned [`crate::serve::ModelSnapshot`] between fused
//! scoring passes (see [`crate::serve::server`] for the consistency
//! guarantees). The serving wire protocol carries ingest via
//! [`crate::serve::wire::ServeMessage::Ingest`], and
//! `python/dpmmwrapper.py`'s `DpmmClient` speaks the same verb — the
//! client wire is identical in local and cluster mode.
//!
//! Benchmarks: `cargo bench --bench stream_ingest` quantifies incremental
//! ingest against a full refit at matched NMI (`BENCH_stream.json`), and
//! `cargo bench --bench stream_distributed` measures 1-vs-2-vs-4-worker
//! ingest throughput (`BENCH_stream_distributed.json`); EXPERIMENTS.md
//! §Streaming and §Distributed streaming have the protocols.
//!
//! The whole path is deterministic — bitwise-identical labels and
//! statistics across thread counts, assignment kernels, *and worker
//! counts* — see the contracts in [`fitter`]'s and [`distributed`]'s docs,
//! pinned by `tests/prop_kernel_equiv.rs`, `tests/prop_stats_roundtrip.rs`,
//! and `tests/integration_stream_distributed.rs`.

pub mod buffer;
pub mod distributed;
pub mod fitter;

pub use buffer::StreamBuffer;
pub use distributed::{DistributedFitter, DistributedStreamConfig};
pub use fitter::{IncrementalFitter, IngestSummary, StreamConfig, StreamFitter};
