//! Streaming-state checkpoints: leader durability for `dpmm stream`.
//!
//! A fit checkpoint (`DPMMCKPT` v1, [`crate::coordinator::checkpoint`])
//! freezes a batch MCMC chain. A **streaming** checkpoint additionally has
//! to capture everything the stream leader needs to replay to a
//! bitwise-identical state after a restart: the RNG lineage, the frozen
//! `base` and windowed `win` accumulators, and the full window contents —
//! raw mini-batch values with their live labels and, in distributed mode,
//! each batch's persistent sweep-RNG stream (collected from the workers
//! via `StreamBatchState` at save time).
//!
//! # File format (`DPMMCKPT` version 3)
//!
//! The file starts with the **same model section as a v1 fit checkpoint**
//! (magic, version byte, α, N, prior, K clusters) so
//! [`crate::serve::ModelSnapshot::from_checkpoint_file`] can serve straight
//! from a streaming checkpoint. The label vector is empty (window labels
//! live in the streaming section), and a `STRM` section follows:
//!
//! ```text
//! [8]  magic  "DPMMCKPT"
//! [1]  version = 3            (v1 = fit checkpoint, no streaming section;
//!                              v2 was never shipped — the number aligns
//!                              with fit-wire protocol v3)
//!      f64 alpha · u64 n_total · prior · u32 K
//!      K × { stats, sub_l, sub_r, f64 weight, f64 sw0, f64 sw1, u64 age }
//!      u64 iter (ingested batches; informational) · u64 n_labels = 0
//! [4]  magic  "STRM"
//! [1]  section version = 1
//! [1]  mode: 0 = local window, 1 = distributed batch FIFO
//! [32] leader RNG state (4 × u64)
//!      u64 ingested points · u64 next_batch_id
//!      u64 window · u32 sweeps · f64 decay · f64 stream alpha
//!      u32 K · K × 2 stats (base) · K × 2 stats (win)
//!      mode 0: u64 wlen · f64s values · wlen × u32 z · wlen × u8 zsub
//!      mode 1: u32 n_batches · n × { u64 id, u32 n, f64s x,
//!                                    n × u32 z, n × u8 zsub, 4 × u64 rng }
//! ```
//!
//! Loading is fully validated: corrupt or truncated streaming sections are
//! **typed errors**, never aborts (`tests/integration_stream_recovery.rs`
//! and the checkpoint tests pin this), and a v1 file is rejected by the
//! resume path with an error that says it has no streaming section — while
//! fit/serve loaders keep accepting v1 files unchanged.
//!
//! The determinism contract for `--resume` (fixed seed + same ingest
//! history ⇒ bitwise-identical stats, across worker counts and kernels)
//! and its boundaries are documented in docs/DETERMINISM.md.

use crate::coordinator::checkpoint::{
    read_f64, read_f64s, read_prior, read_stats, read_u32, read_u64, read_u8, write_f64s,
    write_prior, write_stats, MAGIC,
};
use crate::model::{Cluster, DpmmState};
use crate::stats::{Prior, Stats};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// `DPMMCKPT` version byte for checkpoints carrying a streaming section.
/// v2 was never shipped; the jump keeps the file version aligned with the
/// fit-wire protocol version that introduced leader durability.
pub const STREAM_CHECKPOINT_VERSION: u8 = 3;

/// Streaming-section magic (follows the model section).
const STRM_MAGIC: &[u8; 4] = b"STRM";
const STRM_VERSION: u8 = 1;

/// Cadence/path knobs for periodic leader checkpoints, shared by the local
/// and distributed fitters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamCheckpointCfg {
    /// Checkpoint file path (written atomically: temp file + rename).
    pub path: String,
    /// Save every N successfully ingested batches (0 = only on explicit
    /// [`save`](crate::stream::IncrementalFitter::save_stream_checkpoint)
    /// calls).
    pub every_batches: usize,
}

/// One windowed batch's full dump (distributed mode).
#[derive(Debug, Clone)]
pub struct BatchDump {
    pub id: u64,
    pub x: Vec<f64>,
    pub z: Vec<u32>,
    pub zsub: Vec<u8>,
    pub rng: [u64; 4],
}

/// Window contents by topology.
#[derive(Debug, Clone)]
pub enum WindowContents {
    /// Single-process window: the `StreamBuffer`'s rows and labels.
    Local { values: Vec<f64>, z: Vec<u32>, zsub: Vec<u8> },
    /// Distributed window: the leader's global batch FIFO, ascending id.
    Distributed { batches: Vec<BatchDump> },
}

/// Everything a stream fitter needs to resume bitwise-identically.
#[derive(Debug, Clone)]
pub struct StreamCheckpoint {
    pub alpha_model: f64,
    pub n_total: usize,
    pub prior: Prior,
    pub clusters: Vec<Cluster>,
    /// Leader RNG lineage at save time.
    pub rng: [u64; 4],
    pub ingested: u64,
    pub next_batch_id: u64,
    /// Stream config captured at save time — resume **uses these** (not
    /// the CLI values) because the determinism contract requires the same
    /// window/sweeps/decay/α before and after the restart.
    pub window: usize,
    pub sweeps: usize,
    pub decay: f64,
    pub alpha: f64,
    pub base: Vec<[Stats; 2]>,
    pub win: Vec<[Stats; 2]>,
    pub contents: WindowContents,
}

impl StreamCheckpoint {
    /// Rebuild the coordinator-side model state. Params are deterministic
    /// posterior means — they are resampled from the (exact) statistics at
    /// the first post-resume sweep before anything reads them, so no RNG
    /// is consumed here and the resumed trajectory stays bitwise-aligned.
    pub fn state(&self) -> DpmmState {
        DpmmState {
            alpha: self.alpha,
            prior: self.prior.clone(),
            clusters: self.clusters.clone(),
            n_total: self.n_total,
        }
    }

    pub fn k(&self) -> usize {
        self.clusters.len()
    }
}

fn write_u32v(w: &mut impl Write, v: &[u32]) -> Result<()> {
    for &x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32v(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    (0..n).map(|_| read_u32(r)).collect()
}

fn write_rng(w: &mut impl Write, s: &[u64; 4]) -> Result<()> {
    for &x in s {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Read + validate a serialized RNG state. All-zero is the xoshiro fixed
/// point — unreachable from any legitimately seeded stream, so it can
/// only mean corruption and must be a typed error (a silent fallback
/// would resume a trajectory that is neither the original nor flagged).
fn read_rng(r: &mut impl Read) -> Result<[u64; 4]> {
    let s = [read_u64(r)?, read_u64(r)?, read_u64(r)?, read_u64(r)?];
    if s == [0, 0, 0, 0] {
        bail!("streaming checkpoint holds an all-zero RNG state (corrupt)");
    }
    Ok(s)
}

fn write_bundle(w: &mut impl Write, bundle: &[[Stats; 2]]) -> Result<()> {
    for [l, rr] in bundle {
        write_stats(w, l)?;
        write_stats(w, rr)?;
    }
    Ok(())
}

fn read_bundle(r: &mut impl Read, k: usize, prior: &Prior, what: &str) -> Result<Vec<[Stats; 2]>> {
    let d = prior.dim();
    let mut bundle = Vec::with_capacity(k);
    for kk in 0..k {
        let pair = [read_stats(r)?, read_stats(r)?];
        for s in &pair {
            if s.family() != prior.family() || s.dim() != d {
                bail!(
                    "streaming checkpoint `{what}` stats for cluster {kk} do not match \
                     the prior (family {}, dimension {})",
                    s.family(),
                    s.dim()
                );
            }
        }
        bundle.push(pair);
    }
    Ok(bundle)
}

/// Borrowed view of everything [`save_stream_checkpoint`] serializes.
pub(crate) struct StreamSave<'a> {
    pub state: &'a DpmmState,
    pub rng: [u64; 4],
    pub ingested: u64,
    pub next_batch_id: u64,
    pub window: usize,
    pub sweeps: usize,
    pub decay: f64,
    pub alpha: f64,
    pub base: &'a [[Stats; 2]],
    pub win: &'a [[Stats; 2]],
    pub contents: WindowContents,
}

/// Write a streaming checkpoint atomically (temp file + rename, so an
/// interrupted save never clobbers the previous good checkpoint).
pub(crate) fn save_stream_checkpoint(path: impl AsRef<Path>, s: &StreamSave<'_>) -> Result<()> {
    let path = path.as_ref();
    let tmp = path.with_extension("tmp");
    {
        let mut w = BufWriter::new(
            std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?,
        );
        // Model section (v1-compatible layout under version byte 3).
        w.write_all(MAGIC)?;
        w.write_all(&[STREAM_CHECKPOINT_VERSION])?;
        w.write_all(&s.state.alpha.to_le_bytes())?;
        w.write_all(&(s.state.n_total as u64).to_le_bytes())?;
        write_prior(&mut w, &s.state.prior)?;
        w.write_all(&(s.state.k() as u32).to_le_bytes())?;
        for c in &s.state.clusters {
            write_stats(&mut w, &c.stats)?;
            write_stats(&mut w, &c.sub_stats[0])?;
            write_stats(&mut w, &c.sub_stats[1])?;
            w.write_all(&c.weight.to_le_bytes())?;
            w.write_all(&c.sub_weights[0].to_le_bytes())?;
            w.write_all(&c.sub_weights[1].to_le_bytes())?;
            w.write_all(&(c.age as u64).to_le_bytes())?;
        }
        w.write_all(&s.next_batch_id.to_le_bytes())?; // "iter": informational
        w.write_all(&0u64.to_le_bytes())?; // no global label vector
        // Streaming section.
        w.write_all(STRM_MAGIC)?;
        w.write_all(&[STRM_VERSION])?;
        let mode: u8 = match &s.contents {
            WindowContents::Local { .. } => 0,
            WindowContents::Distributed { .. } => 1,
        };
        w.write_all(&[mode])?;
        write_rng(&mut w, &s.rng)?;
        w.write_all(&s.ingested.to_le_bytes())?;
        w.write_all(&s.next_batch_id.to_le_bytes())?;
        w.write_all(&(s.window as u64).to_le_bytes())?;
        w.write_all(&(s.sweeps as u32).to_le_bytes())?;
        w.write_all(&s.decay.to_le_bytes())?;
        w.write_all(&s.alpha.to_le_bytes())?;
        w.write_all(&(s.state.k() as u32).to_le_bytes())?;
        write_bundle(&mut w, s.base)?;
        write_bundle(&mut w, s.win)?;
        match &s.contents {
            WindowContents::Local { values, z, zsub } => {
                w.write_all(&(z.len() as u64).to_le_bytes())?;
                write_f64s(&mut w, values)?;
                write_u32v(&mut w, z)?;
                w.write_all(zsub)?;
            }
            WindowContents::Distributed { batches } => {
                w.write_all(&(batches.len() as u32).to_le_bytes())?;
                for b in batches {
                    w.write_all(&b.id.to_le_bytes())?;
                    w.write_all(&(b.z.len() as u32).to_le_bytes())?;
                    write_f64s(&mut w, &b.x)?;
                    write_u32v(&mut w, &b.z)?;
                    w.write_all(&b.zsub)?;
                    write_rng(&mut w, &b.rng)?;
                }
            }
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing checkpoint {}", path.display()))?;
    Ok(())
}

/// Load + fully validate a streaming checkpoint. Every corruption class —
/// bad magic, wrong versions, truncation at any depth, label/shape
/// mismatches, non-finite values — is a typed error, never an abort.
pub fn load_stream_checkpoint(path: impl AsRef<Path>) -> Result<StreamCheckpoint> {
    let path = path.as_ref();
    let mut r = BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("reading checkpoint magic")?;
    if &magic != MAGIC {
        bail!("not a dpmm checkpoint (bad magic)");
    }
    let ver = read_u8(&mut r)?;
    if ver == crate::coordinator::checkpoint::VERSION {
        bail!(
            "checkpoint is a version-1 fit checkpoint with no streaming section — \
             `--resume` needs a checkpoint written by `dpmm stream` \
             (start fresh from it with --checkpoint instead)"
        );
    }
    if ver != STREAM_CHECKPOINT_VERSION {
        bail!("unsupported checkpoint version {ver}");
    }
    let alpha_model = read_f64(&mut r)?;
    let n_total = read_u64(&mut r)? as usize;
    let prior = read_prior(&mut r)?;
    let d = prior.dim();
    let k = read_u32(&mut r)? as usize;
    if k == 0 || k > 1 << 16 {
        bail!("implausible cluster count {k} in streaming checkpoint");
    }
    let mut clusters = Vec::with_capacity(k);
    for kk in 0..k {
        let stats = read_stats(&mut r)?;
        let sub_l = read_stats(&mut r)?;
        let sub_r = read_stats(&mut r)?;
        for s in [&stats, &sub_l, &sub_r] {
            if s.family() != prior.family() || s.dim() != d {
                bail!("streaming checkpoint cluster {kk} stats do not match the prior");
            }
        }
        let weight = read_f64(&mut r)?;
        let sw0 = read_f64(&mut r)?;
        let sw1 = read_f64(&mut r)?;
        let age = read_u64(&mut r)? as usize;
        let params = prior
            .try_mean_params(&stats)
            .with_context(|| format!("streaming checkpoint cluster {kk}"))?;
        let sub_params = [
            prior
                .try_mean_params(&sub_l)
                .with_context(|| format!("streaming checkpoint cluster {kk} (left sub)"))?,
            prior
                .try_mean_params(&sub_r)
                .with_context(|| format!("streaming checkpoint cluster {kk} (right sub)"))?,
        ];
        clusters.push(Cluster {
            stats,
            sub_stats: [sub_l, sub_r],
            params,
            sub_params,
            weight,
            sub_weights: [sw0, sw1],
            age,
            since_restart: 0,
        });
    }
    let _iter = read_u64(&mut r)?;
    let n_labels = read_u64(&mut r)? as usize;
    if n_labels != 0 {
        bail!("streaming checkpoint carries a global label vector ({n_labels} labels)");
    }
    let mut strm = [0u8; 4];
    r.read_exact(&mut strm).context("reading streaming section magic")?;
    if &strm != STRM_MAGIC {
        bail!("streaming checkpoint has a corrupt streaming-section header");
    }
    let sver = read_u8(&mut r)?;
    if sver != STRM_VERSION {
        bail!("unsupported streaming-section version {sver}");
    }
    let mode = read_u8(&mut r)?;
    if mode > 1 {
        bail!("bad streaming-section mode byte {mode} (0 = local, 1 = distributed)");
    }
    let rng = read_rng(&mut r)?;
    let ingested = read_u64(&mut r)?;
    let next_batch_id = read_u64(&mut r)?;
    let window = read_u64(&mut r)? as usize;
    let sweeps = read_u32(&mut r)? as usize;
    let decay = read_f64(&mut r)?;
    let alpha = read_f64(&mut r)?;
    if window == 0 || window > 1 << 40 {
        bail!("streaming checkpoint has implausible window capacity {window}");
    }
    if sweeps > 1 << 16 {
        bail!("streaming checkpoint has implausible sweep count {sweeps}");
    }
    if !(decay > 0.0 && decay <= 1.0) {
        bail!("streaming checkpoint has invalid decay {decay}");
    }
    if !alpha.is_finite() || alpha <= 0.0 {
        bail!("streaming checkpoint has invalid stream alpha {alpha}");
    }
    let sk = read_u32(&mut r)? as usize;
    if sk != k {
        bail!("streaming section cluster count {sk} != model section {k}");
    }
    let base = read_bundle(&mut r, k, &prior, "base")?;
    let win = read_bundle(&mut r, k, &prior, "win")?;
    let check_labels = |z: &[u32], zsub: &[u8], what: &str| -> Result<()> {
        if z.iter().any(|&l| l as usize >= k) {
            bail!("streaming checkpoint {what} has labels out of range (K = {k})");
        }
        if zsub.iter().any(|&s| s > 1) {
            bail!("streaming checkpoint {what} has sub-labels out of range");
        }
        Ok(())
    };
    let contents = match mode {
        0 => {
            let wlen = read_u64(&mut r)? as usize;
            if wlen > window {
                bail!("streaming checkpoint window holds {wlen} points over its {window} cap");
            }
            let values = read_f64s(&mut r)?;
            if values.len() != wlen * d {
                bail!(
                    "streaming checkpoint window values have length {} for {wlen} points \
                     of dimension {d}",
                    values.len()
                );
            }
            if values.iter().any(|v| !v.is_finite()) {
                bail!("streaming checkpoint window has non-finite values");
            }
            let z = read_u32v(&mut r, wlen)?;
            let mut zsub = vec![0u8; wlen];
            r.read_exact(&mut zsub).context("reading window sub-labels")?;
            check_labels(&z, &zsub, "window")?;
            WindowContents::Local { values, z, zsub }
        }
        _ => {
            let n_batches = read_u32(&mut r)? as usize;
            if n_batches > 1 << 20 {
                bail!("streaming checkpoint has implausible batch count {n_batches}");
            }
            let mut batches = Vec::with_capacity(n_batches);
            let mut last_id: Option<u64> = None;
            for _ in 0..n_batches {
                let id = read_u64(&mut r)?;
                if let Some(prev) = last_id {
                    if id <= prev {
                        bail!("streaming checkpoint batch FIFO is not ascending ({prev} → {id})");
                    }
                }
                if id >= next_batch_id {
                    bail!("streaming checkpoint batch id {id} >= next_batch_id {next_batch_id}");
                }
                last_id = Some(id);
                let n = read_u32(&mut r)? as usize;
                if n == 0 || n > window {
                    bail!("streaming checkpoint batch {id} has implausible size {n}");
                }
                let x = read_f64s(&mut r)?;
                if x.len() != n * d {
                    bail!(
                        "streaming checkpoint batch {id} values have length {} for {n} \
                         points of dimension {d}",
                        x.len()
                    );
                }
                if x.iter().any(|v| !v.is_finite()) {
                    bail!("streaming checkpoint batch {id} has non-finite values");
                }
                let z = read_u32v(&mut r, n)?;
                let mut zsub = vec![0u8; n];
                r.read_exact(&mut zsub)
                    .with_context(|| format!("reading batch {id} sub-labels"))?;
                check_labels(&z, &zsub, "batch")?;
                let brng = read_rng(&mut r)?;
                batches.push(BatchDump { id, x, z, zsub, rng: brng });
            }
            WindowContents::Distributed { batches }
        }
    };
    Ok(StreamCheckpoint {
        alpha_model,
        n_total,
        prior,
        clusters,
        rng,
        ingested,
        next_batch_id,
        window,
        sweeps,
        decay,
        alpha,
        base,
        win,
        contents,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::stats::NiwPrior;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dpmm_sckpt_{name}_{}.bin", std::process::id()))
    }

    fn sample_save() -> (DpmmState, Vec<[Stats; 2]>, Vec<[Stats; 2]>) {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut state = DpmmState::new(2.0, prior.clone(), 2, 40, &mut rng);
        let mut base = Vec::new();
        let mut win = Vec::new();
        for (ci, c) in state.clusters.iter_mut().enumerate() {
            let mut s = prior.empty_stats();
            s.add(&[ci as f64 * 4.0, 1.0]);
            s.add(&[ci as f64 * 4.0 + 0.5, -1.0]);
            c.stats = s.clone();
            let mut half = s.clone();
            half.decay(0.5);
            c.sub_stats = [half.clone(), half.clone()];
            base.push([half.clone(), half.clone()]);
            win.push([prior.empty_stats(), prior.empty_stats()]);
        }
        (state, base, win)
    }

    #[test]
    fn local_roundtrip_is_exact() {
        let (state, base, win) = sample_save();
        let save = StreamSave {
            state: &state,
            rng: [11, 22, 33, 44],
            ingested: 9,
            next_batch_id: 0,
            window: 64,
            sweeps: 2,
            decay: 0.9,
            alpha: 3.0,
            base: &base,
            win: &win,
            contents: WindowContents::Local {
                values: vec![0.5, -0.5, 1.0, 2.0],
                z: vec![0, 1],
                zsub: vec![1, 0],
            },
        };
        let p = tmp("local");
        save_stream_checkpoint(&p, &save).unwrap();
        let back = load_stream_checkpoint(&p).unwrap();
        assert_eq!(back.rng, [11, 22, 33, 44]);
        assert_eq!(back.ingested, 9);
        assert_eq!((back.window, back.sweeps), (64, 2));
        assert_eq!((back.decay, back.alpha), (0.9, 3.0));
        assert_eq!(back.k(), 2);
        assert_eq!(back.base, base);
        assert_eq!(back.win, win);
        match &back.contents {
            WindowContents::Local { values, z, zsub } => {
                assert_eq!(values, &vec![0.5, -0.5, 1.0, 2.0]);
                assert_eq!(z, &vec![0, 1]);
                assert_eq!(zsub, &vec![1, 0]);
            }
            _ => panic!("wrong mode"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn distributed_roundtrip_is_exact() {
        let (state, base, win) = sample_save();
        let batches = vec![
            BatchDump { id: 3, x: vec![1.0, 2.0], z: vec![0], zsub: vec![0], rng: [1, 2, 3, 4] },
            BatchDump {
                id: 7,
                x: vec![3.0, 4.0, 5.0, 6.0],
                z: vec![1, 1],
                zsub: vec![0, 1],
                rng: [5, 6, 7, 8],
            },
        ];
        let save = StreamSave {
            state: &state,
            rng: [9, 9, 9, 9],
            ingested: 3,
            next_batch_id: 8,
            window: 128,
            sweeps: 1,
            decay: 1.0,
            alpha: 2.0,
            base: &base,
            win: &win,
            contents: WindowContents::Distributed { batches: batches.clone() },
        };
        let p = tmp("dist");
        save_stream_checkpoint(&p, &save).unwrap();
        let back = load_stream_checkpoint(&p).unwrap();
        assert_eq!(back.next_batch_id, 8);
        match &back.contents {
            WindowContents::Distributed { batches: got } => {
                assert_eq!(got.len(), 2);
                for (a, b) in got.iter().zip(&batches) {
                    assert_eq!((a.id, &a.x, &a.z, &a.zsub, a.rng), (b.id, &b.x, &b.z, &b.zsub, b.rng));
                }
            }
            _ => panic!("wrong mode"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_fit_checkpoints_are_rejected_with_a_clear_error() {
        use crate::coordinator::Checkpoint;
        let (state, _, _) = sample_save();
        let n = state.n_total;
        let ckpt = Checkpoint { state, iter: 5, labels: vec![0; n] };
        let p = tmp("v1");
        ckpt.save(&p).unwrap();
        let err = load_stream_checkpoint(&p).unwrap_err();
        assert!(err.to_string().contains("no streaming section"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupt_streaming_sections_are_typed_errors() {
        let (state, base, win) = sample_save();
        let save = StreamSave {
            state: &state,
            rng: [1, 2, 3, 4],
            ingested: 2,
            next_batch_id: 1,
            window: 32,
            sweeps: 1,
            decay: 1.0,
            alpha: 2.0,
            base: &base,
            win: &win,
            contents: WindowContents::Distributed {
                batches: vec![BatchDump {
                    id: 0,
                    x: vec![1.0, 2.0],
                    z: vec![0],
                    zsub: vec![1],
                    rng: [4, 3, 2, 1],
                }],
            },
        };
        let p = tmp("corrupt");
        save_stream_checkpoint(&p, &save).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // Truncation at many depths (incl. inside the streaming section).
        for cut in [9, 40, bytes.len() / 2, bytes.len() - 37, bytes.len() - 1] {
            std::fs::write(&p, &bytes[..cut.min(bytes.len() - 1)]).unwrap();
            assert!(load_stream_checkpoint(&p).is_err(), "cut={cut}");
        }
        // Corrupt STRM magic.
        let strm_at = bytes
            .windows(4)
            .position(|w| w == STRM_MAGIC)
            .expect("streaming section present");
        let mut bad = bytes.clone();
        bad[strm_at] = b'X';
        std::fs::write(&p, &bad).unwrap();
        let err = load_stream_checkpoint(&p).unwrap_err();
        assert!(err.to_string().contains("streaming-section"), "{err}");
        // Bad mode byte.
        let mut bad = bytes.clone();
        bad[strm_at + 5] = 9;
        std::fs::write(&p, &bad).unwrap();
        assert!(load_stream_checkpoint(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
