//! Sliding-window point buffer for the streaming fitter.
//!
//! The buffer holds the most recent `capacity` ingested points row-major,
//! together with their current cluster and sub-cluster labels — exactly the
//! per-point state a fit-path [`crate::backend::shard::Shard`] carries, but
//! FIFO: new mini-batches append at the back and the oldest points scroll
//! off the front once capacity is exceeded. Only windowed points are
//! resweepable; everything older is frozen evidence held as sufficient
//! statistics by the [`IncrementalFitter`](crate::stream::IncrementalFitter).

/// FIFO window of recent points with their labels.
#[derive(Debug, Clone)]
pub struct StreamBuffer {
    d: usize,
    capacity: usize,
    values: Vec<f64>,
    z: Vec<u32>,
    zsub: Vec<u8>,
}

impl StreamBuffer {
    pub fn new(d: usize, capacity: usize) -> Self {
        assert!(d > 0, "stream buffer needs a positive dimension");
        assert!(capacity > 0, "stream buffer needs a positive capacity");
        Self { d, capacity, values: Vec::new(), z: Vec::new(), zsub: Vec::new() }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Points currently windowed.
    pub fn len(&self) -> usize {
        self.z.len()
    }

    pub fn is_empty(&self) -> bool {
        self.z.is_empty()
    }

    /// Row-major point values (`len() × d`).
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Current cluster label per windowed point.
    pub fn labels(&self) -> &[u32] {
        &self.z
    }

    /// Current sub-cluster label per windowed point.
    pub fn sub_labels(&self) -> &[u8] {
        &self.zsub
    }

    /// Append a batch with its (seeded) labels at the back of the window.
    /// Does not evict — the caller folds overflow into its frozen base
    /// first (it needs the evicted points' labels), then calls
    /// [`Self::evict_front`].
    pub fn push(&mut self, values: &[f64], z: &[u32], zsub: &[u8]) {
        let n = z.len();
        assert_eq!(values.len(), n * self.d, "batch shape mismatch");
        assert_eq!(zsub.len(), n, "sub-label length mismatch");
        self.values.extend_from_slice(values);
        self.z.extend_from_slice(z);
        self.zsub.extend_from_slice(zsub);
    }

    /// Number of points past capacity (to be evicted from the front).
    pub fn overflow(&self) -> usize {
        self.len().saturating_sub(self.capacity)
    }

    /// Drop the `n` oldest points.
    pub fn evict_front(&mut self, n: usize) {
        let n = n.min(self.len());
        self.values.drain(..n * self.d);
        self.z.drain(..n);
        self.zsub.drain(..n);
    }

    /// Drop `n` points starting at point index `start` (any position — the
    /// distributed worker removes whole batches from the middle of its
    /// window slice on rebalance and on out-of-FIFO-order eviction after a
    /// rebalance).
    pub fn remove_span(&mut self, start: usize, n: usize) {
        assert!(start + n <= self.len(), "remove_span out of range");
        self.values.drain(start * self.d..(start + n) * self.d);
        self.z.drain(start..start + n);
        self.zsub.drain(start..start + n);
    }

    /// Temporarily take ownership of the window's value buffer — a
    /// zero-copy hand-off to a sweep's [`crate::datagen::Data`] so the
    /// whole window is not cloned on every ingest. Pair with
    /// [`Self::restore_values`]; the buffer must not be pushed to or
    /// evicted from in between.
    pub(crate) fn take_values(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.values)
    }

    /// Return the buffer taken by [`Self::take_values`].
    pub(crate) fn restore_values(&mut self, values: Vec<f64>) {
        debug_assert!(self.values.is_empty(), "restore over live values");
        debug_assert_eq!(values.len(), self.z.len() * self.d, "restored shape mismatch");
        self.values = values;
    }

    /// Replace every windowed point's labels (post-sweep write-back).
    pub fn set_labels(&mut self, z: Vec<u32>, zsub: Vec<u8>) {
        assert_eq!(z.len(), self.len(), "label write-back length mismatch");
        assert_eq!(zsub.len(), self.len(), "sub-label write-back length mismatch");
        self.z = z;
        self.zsub = zsub;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_evict_fifo() {
        let mut b = StreamBuffer::new(2, 3);
        b.push(&[1.0, 2.0, 3.0, 4.0], &[0, 1], &[0, 1]);
        assert_eq!(b.len(), 2);
        assert_eq!(b.overflow(), 0);
        b.push(&[5.0, 6.0, 7.0, 8.0], &[0, 0], &[1, 0]);
        assert_eq!(b.len(), 4);
        assert_eq!(b.overflow(), 1);
        b.evict_front(1);
        assert_eq!(b.len(), 3);
        assert_eq!(b.values(), &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(b.labels(), &[1, 0, 0]);
        assert_eq!(b.sub_labels(), &[1, 1, 0]);
    }

    #[test]
    fn remove_span_mid_window() {
        let mut b = StreamBuffer::new(2, 16);
        b.push(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], &[0, 1, 2, 3], &[0, 1, 0, 1]);
        b.remove_span(1, 2);
        assert_eq!(b.len(), 2);
        assert_eq!(b.values(), &[1.0, 2.0, 7.0, 8.0]);
        assert_eq!(b.labels(), &[0, 3]);
        assert_eq!(b.sub_labels(), &[0, 1]);
    }

    #[test]
    fn label_writeback() {
        let mut b = StreamBuffer::new(1, 8);
        b.push(&[0.5, 1.5], &[0, 0], &[0, 0]);
        b.set_labels(vec![1, 2], vec![1, 0]);
        assert_eq!(b.labels(), &[1, 2]);
        assert_eq!(b.sub_labels(), &[1, 0]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn rejects_bad_shape() {
        let mut b = StreamBuffer::new(3, 4);
        b.push(&[1.0, 2.0], &[0], &[0]);
    }
}
