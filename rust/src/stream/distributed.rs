//! Distributed streaming ingest: the leader side of `dpmm stream
//! --workers=host:port,...`.
//!
//! The local [`IncrementalFitter`](super::IncrementalFitter) caps ingest
//! throughput and window size at one machine's cores and RAM. This module
//! shards the stream across the same TCP workers the batch backend uses
//! (`dpmm worker`): the leader routes each ingest mini-batch to the
//! least-loaded worker's window slice, workers MAP-seed and resweep their
//! slices locally, and only **grouped sufficient-statistics deltas**
//! ([`BatchDelta`]) cross the wire per sweep — O(K·d²) per changed batch,
//! never O(N·d), the paper's low-bandwidth distribution property carried
//! over to streaming.
//!
//! # Division of labor
//!
//! The **leader** ([`DistributedFitter`]) owns exactly what the local
//! fitter's coordinator half owns: the model state, the frozen `base` and
//! windowed `win` accumulators, the single RNG that samples weights and
//! parameters, and the **global batch FIFO** that decides eviction. For
//! durability and recovery it additionally retains, per windowed batch,
//! the raw mini-batch values and a **mirror** of that batch's current
//! contribution to `win` (maintained from the same deltas it folds).
//! Per ingested batch it runs the same five phases as the local fitter
//! (decay → seed → fold → evict → `sweeps` restricted sweeps), with the
//! seed and sweep phases executing worker-side.
//!
//! # Fault tolerance and elasticity (PR 5)
//!
//! * **Worker failure** no longer poisons the stream: the leader marks the
//!   worker dead, retires each of its resident batches' mirrors from
//!   `win`, and re-ingests the retained raw batches onto survivors through
//!   the ordinary `StreamIngest` path (MAP re-seed under the current
//!   [`StepParams::map_snapshot`], fresh leader-forked RNG streams). The
//!   stream continues; `/stats` surfaces a typed degraded mode. Only
//!   losing the *last* live worker halts ingest.
//! * **Elastic membership**: [`DistributedFitter::join_worker`] /
//!   [`DistributedFitter::remove_worker`] move whole batches between live
//!   workers with labels and RNG streams intact (`StreamRebalance` →
//!   `StreamRestore`), so planned churn never forks the model trajectory.
//! * **Leader durability**: [`DistributedFitter::save_stream_checkpoint`]
//!   captures the full streaming state (worker label/RNG state collected
//!   via `StreamBatchState`) into a `DPMMCKPT` v3 file;
//!   [`DistributedFitter::resume`] replays it to a bitwise-identical
//!   leader state — across *any* worker count, because ownership never
//!   affects the trajectory.
//!
//! The determinism contract (what is bitwise-stable across worker counts,
//! kernels, restarts, and planned churn — and exactly where unplanned
//! failures legitimately fork the RNG lineage) is documented in
//! docs/DETERMINISM.md and pinned by
//! `tests/integration_stream_distributed.rs` and
//! `tests/integration_stream_recovery.rs`.

use super::checkpoint::{
    load_stream_checkpoint, save_stream_checkpoint, BatchDump, StreamCheckpointCfg, StreamSave,
    WindowContents,
};
use super::fitter::{
    fold_groups, seed_state_from_snapshot, sync_model_stats, IngestSummary, StreamFitter,
    StreamHealth,
};
use super::supervisor::{EventLog, Liveness, Supervisor, SupervisorConfig};
use crate::backend::distributed::wire::{
    self, request, write_message, BatchDelta, BatchState, Message, RetryPolicy,
};
use crate::backend::shard::AssignKernel;
use crate::model::DpmmState;
use crate::rng::{Rng, Xoshiro256pp};
use crate::sampler::{
    sample_params, sample_sub_weights, sample_weights, SamplerOptions, StepParams,
};
use crate::serve::ModelSnapshot;
use crate::stats::{Prior, Stats};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;

/// Salt XOR-ed into `cfg.seed` for the connect-retry jitter stream: the
/// jitter RNG must be deterministic under a fixed seed (reproducible retry
/// schedules) yet fully decoupled from the model RNG lineage, so retries
/// can never perturb a trajectory.
const RETRY_SEED_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// Distributed streaming knobs (the leader-side analog of
/// [`super::StreamConfig`]; per-worker thread/kernel execution is
/// configured at `StreamInit` instead of per-sweep).
#[derive(Debug, Clone)]
pub struct DistributedStreamConfig {
    /// Worker addresses (`host:port`), each running `dpmm worker`.
    pub workers: Vec<String>,
    /// Sweep threads per worker process.
    pub worker_threads: usize,
    /// Global sliding-window capacity in points (across all workers).
    /// Eviction is batch-granular in global FIFO order.
    pub window: usize,
    /// Restricted-Gibbs sweeps over the window per ingested batch.
    pub sweeps: usize,
    /// Exponential forgetting factor applied to the frozen base per ingest.
    pub decay: f64,
    /// DP concentration for the restricted sweeps.
    pub alpha: f64,
    /// RNG seed for the leader's weight/parameter draws and the per-batch
    /// sweep-stream forks.
    pub seed: u64,
    /// Assignment kernel shipped to every worker (`None` = each worker's
    /// own `DPMM_ASSIGN_KERNEL` environment decides).
    pub kernel: Option<AssignKernel>,
    /// Periodic leader checkpointing (`None` = only explicit
    /// [`DistributedFitter::save_stream_checkpoint`] calls).
    pub checkpoint: Option<StreamCheckpointCfg>,
    /// Heartbeat probe interval in milliseconds (`0` = supervision
    /// disabled, the default). When enabled, a leader-side supervisor
    /// thread pings every worker's control socket and rates it `Healthy →
    /// Suspect → Dead`; `Dead` workers are proactively evicted (their
    /// batches re-shard onto survivors) instead of waiting for sweep I/O
    /// to fail (see [`super::supervisor`]).
    pub heartbeat_ms: u64,
    /// How long probes may fail (since the last successful pong) before a
    /// worker is rated `Dead` and evicted.
    pub heartbeat_grace_ms: u64,
    /// Maximum connect attempts per worker-session open (`1` = no retry).
    /// A transient socket blip absorbed here costs nothing: the model RNG
    /// is untouched, so the trajectory is bitwise-identical to a
    /// fault-free run.
    pub connect_retries: u32,
    /// Exponential-backoff base delay between connect retries (ms).
    pub retry_base_ms: u64,
    /// Backoff delay cap (ms).
    pub retry_max_ms: u64,
}

impl Default for DistributedStreamConfig {
    fn default() -> Self {
        Self {
            workers: Vec::new(),
            worker_threads: 1,
            window: 32 * 1024,
            sweeps: 2,
            decay: 1.0,
            alpha: 10.0,
            seed: 0,
            kernel: None,
            checkpoint: None,
            heartbeat_ms: 0,
            heartbeat_grace_ms: 3000,
            connect_retries: 3,
            retry_base_ms: 50,
            retry_max_ms: 2000,
        }
    }
}

/// One worker connection slot. Slots are never removed: a dead worker
/// stays as a tombstone (`conn = None`, `retired = false`) so `/stats`
/// honestly reports the failure; a gracefully removed worker is marked
/// `retired` and does not count as degraded.
struct WorkerSlot {
    addr: String,
    conn: Option<TcpStream>,
    /// Windowed points resident on this worker (the routing load measure).
    points: usize,
    /// Left via [`DistributedFitter::remove_worker`] (planned, not a failure).
    retired: bool,
}

/// One windowed batch in the leader's global FIFO (ascending `id`).
struct BatchRec {
    id: u64,
    owner: usize,
    n: usize,
    /// Raw row-major values, retained for durability: recovery re-ships
    /// them after a worker death; checkpoints persist them. Costs
    /// O(window·d) leader memory — the price of not losing the window.
    x: Vec<f64>,
    /// Mirror of this batch's current contribution to `win`, maintained
    /// from the same deltas the leader folds. Empty = nothing folded
    /// (transient, mid-recovery only). Recovery retires exactly this from
    /// `win` when the owning worker dies.
    stats: Vec<[Stats; 2]>,
}

fn kernel_byte(kernel: Option<AssignKernel>) -> u8 {
    match kernel {
        None => 0,
        Some(AssignKernel::Tiled) => 1,
        Some(AssignKernel::Scalar) => 2,
        Some(AssignKernel::DeviceEmu) => 3,
    }
}

/// Connect to a worker and open a streaming session (`StreamInit` for a
/// fresh cluster, `StreamJoin` for elastic mid-session joins).
fn open_session(
    addr: &str,
    prior: &Prior,
    threads: usize,
    kernel: u8,
    join: bool,
) -> Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to stream worker {addr}"))?;
    wire::configure_stream(&stream)
        .with_context(|| format!("configuring socket to stream worker {addr}"))?;
    let d = prior.dim() as u32;
    let prior = prior.clone();
    let threads = threads.max(1) as u32;
    let msg = if join {
        Message::StreamJoin { d, prior, threads, kernel }
    } else {
        Message::StreamInit { d, prior, threads, kernel }
    };
    match request(&mut stream, &msg)? {
        Message::Ack => Ok(stream),
        other => bail!("worker {addr} session-open reply: {other:?}"),
    }
}

/// [`open_session`] under the connect-retry policy: transient socket blips
/// (refused / reset / mid-frame EOF) are retried with bounded seeded
/// backoff, each retry logged as a structured `retry` event; fatal errors
/// (protocol-level) short-circuit. See `wire::classify_error`.
fn open_session_retry(
    addr: &str,
    prior: &Prior,
    threads: usize,
    kernel: u8,
    join: bool,
    retry: &mut RetryPolicy,
    events: &EventLog,
) -> Result<TcpStream> {
    retry.run(
        &format!("open stream session to {addr}"),
        || open_session(addr, prior, threads, kernel, join),
        |ev| {
            events.emit(
                "retry",
                vec![
                    ("what", Json::from(ev.what)),
                    ("addr", Json::from(addr)),
                    ("attempt", Json::from(ev.attempt as usize)),
                    ("max_attempts", Json::from(ev.max_attempts as usize)),
                    ("delay_ms", Json::from(ev.delay.as_millis() as f64)),
                    ("error", Json::from(format!("{:#}", ev.error))),
                ],
            );
        },
    )
}

/// Start the heartbeat supervisor if the config enables it.
fn spawn_supervisor(
    cfg: &DistributedStreamConfig,
    addrs: &[String],
    events: &Arc<EventLog>,
) -> Option<Supervisor> {
    (cfg.heartbeat_ms > 0).then(|| {
        Supervisor::spawn(
            addrs,
            SupervisorConfig::new(cfg.heartbeat_ms, cfg.heartbeat_grace_ms),
            Arc::clone(events),
        )
    })
}

/// Leader of a distributed streaming cluster: implements the same
/// [`StreamFitter`] surface as the local fitter, with sweeps executed by
/// TCP workers, worker-failure recovery, elastic membership, and
/// checkpointed durability (see the module docs).
///
/// ```no_run
/// use dpmm::serve::ModelSnapshot;
/// use dpmm::stream::{DistributedFitter, DistributedStreamConfig};
///
/// let snapshot = ModelSnapshot::from_checkpoint_file("fit.ckpt")?;
/// let mut fitter = DistributedFitter::from_snapshot(
///     &snapshot,
///     DistributedStreamConfig {
///         workers: vec!["10.0.0.1:7878".into(), "10.0.0.2:7878".into()],
///         window: 1 << 20,
///         ..DistributedStreamConfig::default()
///     },
/// )?;
/// fitter.ingest(&[0.5, -0.25, 1.0, 2.0])?; // two 2-d points
/// fitter.join_worker("10.0.0.3:7878")?; // elastic scale-out, no fork
/// fitter.save_stream_checkpoint("stream.ckpt")?; // durable leader state
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct DistributedFitter {
    state: DpmmState,
    /// Frozen evidence per (cluster, sub): seed snapshot + everything
    /// evicted from the window.
    base: Vec<[Stats; 2]>,
    /// The distributed window's live contribution per (cluster, sub) —
    /// maintained exclusively by the leader's canonical delta folds.
    win: Vec<[Stats; 2]>,
    slots: Vec<WorkerSlot>,
    /// Windowed batches, oldest first (ascending global batch id).
    fifo: VecDeque<BatchRec>,
    window_points: usize,
    next_batch_id: u64,
    rng: Xoshiro256pp,
    cfg: DistributedStreamConfig,
    ingested: u64,
    batches_since_ckpt: usize,
    /// First worker-failure description. Latches for the session (the
    /// tombstone slot stays visible in `/stats`); a `--resume` restart
    /// begins clean.
    degraded: Option<String>,
    /// Set when ingest can no longer continue (no live workers, or a
    /// leader-side fold invariant broke). Every further ingest fails fast
    /// with this reason; recovery is `dpmm stream --resume` from the last
    /// checkpoint (or a fresh start from a snapshot).
    halted: Option<String>,
    /// Structured recovery event log (shared with the supervisor thread
    /// and the retry callbacks; see [`EventLog`]).
    events: Arc<EventLog>,
    /// Heartbeat registry (`None` = supervision disabled). Verdicts are
    /// consumed by [`Self::poll_supervision`].
    supervisor: Option<Supervisor>,
    /// Connect-retry policy with its own seeded jitter stream (never the
    /// model RNG).
    retry: RetryPolicy,
}

impl DistributedFitter {
    /// Connect to the workers, open a streaming session on each, and seed
    /// the leader model from a frozen snapshot (the same seeding path as
    /// the local fitter, so fixed-seed histories start bitwise-identical).
    pub fn from_snapshot(
        snap: &ModelSnapshot,
        cfg: DistributedStreamConfig,
    ) -> Result<DistributedFitter> {
        if cfg.workers.is_empty() {
            bail!("distributed streaming needs at least one worker address (--workers=host:port,...)");
        }
        if !(cfg.decay > 0.0 && cfg.decay <= 1.0) {
            bail!("stream decay must be in (0, 1], got {}", cfg.decay);
        }
        if !(cfg.alpha > 0.0) {
            bail!("stream alpha must be positive, got {}", cfg.alpha);
        }
        let (state, base) = seed_state_from_snapshot(snap, cfg.alpha)?;
        let k = state.k();
        let prior = state.prior.clone();
        let win: Vec<[Stats; 2]> = prior.empty_bundle(k);
        let kb = kernel_byte(cfg.kernel);
        let events = EventLog::from_env();
        let mut retry = RetryPolicy::new(
            cfg.connect_retries,
            cfg.retry_base_ms,
            cfg.retry_max_ms,
            cfg.seed ^ RETRY_SEED_SALT,
        );
        let mut slots = Vec::with_capacity(cfg.workers.len());
        for addr in &cfg.workers {
            let conn =
                open_session_retry(addr, &prior, cfg.worker_threads, kb, false, &mut retry, &events)?;
            slots.push(WorkerSlot { addr: addr.clone(), conn: Some(conn), points: 0, retired: false });
        }
        let supervisor = spawn_supervisor(&cfg, &cfg.workers, &events);
        let seed = cfg.seed;
        Ok(DistributedFitter {
            state,
            base,
            win,
            slots,
            fifo: VecDeque::new(),
            window_points: 0,
            next_batch_id: 0,
            rng: Xoshiro256pp::seed_from_u64(seed),
            cfg,
            ingested: 0,
            batches_since_ckpt: 0,
            degraded: None,
            halted: None,
            events,
            supervisor,
            retry,
        })
    }

    /// Resume a distributed stream from a leader checkpoint written by
    /// [`Self::save_stream_checkpoint`]: the model, accumulators, RNG
    /// lineage, and every windowed batch (values + labels + per-batch
    /// sweep-RNG streams) are restored exactly, and the batches are
    /// redistributed across `cfg.workers` least-loaded-first. Because the
    /// trajectory is ownership-independent, the resumed stream is
    /// **bitwise-identical** to the uninterrupted one for any worker
    /// count. `window`/`sweeps`/`decay`/`alpha` come from the checkpoint
    /// (the contract requires them unchanged); `workers`, threads, and
    /// kernel come from `cfg`.
    pub fn resume(
        path: impl AsRef<Path>,
        cfg: DistributedStreamConfig,
    ) -> Result<DistributedFitter> {
        if cfg.workers.is_empty() {
            bail!("resuming a distributed stream needs at least one worker address");
        }
        let ck = load_stream_checkpoint(&path)?;
        let batches = match ck.contents {
            WindowContents::Distributed { ref batches } => batches.clone(),
            WindowContents::Local { .. } => bail!(
                "checkpoint {} holds a local (single-process) window — resume it \
                 without --workers",
                path.as_ref().display()
            ),
        };
        let mut state = ck.state();
        sync_model_stats(&mut state, &ck.base, &ck.win);
        let prior = state.prior.clone();
        let k = state.k();
        let d = prior.dim();
        let kb = kernel_byte(cfg.kernel);
        let events = EventLog::from_env();
        let mut retry = RetryPolicy::new(
            cfg.connect_retries,
            cfg.retry_base_ms,
            cfg.retry_max_ms,
            cfg.seed ^ RETRY_SEED_SALT,
        );
        let mut slots = Vec::with_capacity(cfg.workers.len());
        for addr in &cfg.workers {
            let conn =
                open_session_retry(addr, &prior, cfg.worker_threads, kb, false, &mut retry, &events)?;
            slots.push(WorkerSlot { addr: addr.clone(), conn: Some(conn), points: 0, retired: false });
        }
        let supervisor = spawn_supervisor(&cfg, &cfg.workers, &events);
        let mut fitter = DistributedFitter {
            state,
            base: ck.base,
            win: ck.win,
            slots,
            fifo: VecDeque::new(),
            window_points: 0,
            next_batch_id: ck.next_batch_id,
            rng: Xoshiro256pp::from_state(ck.rng),
            cfg: DistributedStreamConfig {
                window: ck.window,
                sweeps: ck.sweeps,
                decay: ck.decay,
                alpha: ck.alpha,
                ..cfg
            },
            ingested: ck.ingested,
            batches_since_ckpt: 0,
            degraded: None,
            halted: None,
            events,
            supervisor,
            retry,
        };
        // Re-install every batch verbatim, ascending id, least-loaded
        // worker first (ownership is trajectory-neutral).
        for b in &batches {
            let n = b.z.len();
            let owner = fitter.route_owner()?;
            let msg = Message::StreamRestore {
                batch_id: b.id,
                k: k as u32,
                x: b.x.clone(),
                z: b.z.clone(),
                zsub: b.zsub.clone(),
                rng: b.rng,
            };
            match fitter.request_to(owner, &msg)? {
                Message::Ack => {}
                other => bail!("worker {owner} StreamRestore reply: {other:?}"),
            }
            let mut mirror = prior.empty_bundle(k);
            let sel: Vec<u32> = (0..n as u32).collect();
            fold_groups(&mut mirror, &b.x, d, &sel, &b.z, &b.zsub, true);
            fitter.fifo.push_back(BatchRec {
                id: b.id,
                owner,
                n,
                x: b.x.clone(),
                stats: mirror,
            });
            fitter.slots[owner].points += n;
            fitter.window_points += n;
        }
        Ok(fitter)
    }

    pub fn k(&self) -> usize {
        self.state.k()
    }

    pub fn dim(&self) -> usize {
        self.state.prior.dim()
    }

    /// Worker slots that have not gracefully retired (live + failed).
    pub fn num_workers(&self) -> usize {
        self.slots.iter().filter(|s| !s.retired).count()
    }

    /// Workers currently reachable.
    pub fn workers_alive(&self) -> usize {
        self.slots.iter().filter(|s| s.conn.is_some()).count()
    }

    /// Windowed points per worker slot (dead/retired slots report 0).
    pub fn worker_points(&self) -> Vec<usize> {
        self.slots.iter().map(|s| s.points).collect()
    }

    /// Points ingested over the fitter's lifetime.
    pub fn ingested(&self) -> u64 {
        self.ingested
    }

    /// Points currently resweepable across all worker window slices.
    pub fn window_len(&self) -> usize {
        self.window_points
    }

    /// Per-cluster point masses (base + window evidence).
    pub fn counts(&self) -> Vec<f64> {
        self.state.counts()
    }

    pub fn state(&self) -> &DpmmState {
        &self.state
    }

    /// Cluster liveness/degradation summary (what `/stats` surfaces).
    /// With supervision enabled the healthy/suspect/dead counts are the
    /// heartbeat registry's live verdicts; without it, every reachable
    /// worker counts as healthy and every failed slot as dead.
    pub fn health(&self) -> StreamHealth {
        let total = self.num_workers() as u32;
        let alive = self.workers_alive() as u32;
        let (healthy, suspect, dead_live) = match &self.supervisor {
            Some(sup) => sup.counts(),
            None => (alive, 0, 0),
        };
        StreamHealth {
            workers_total: total,
            workers_alive: alive,
            workers_healthy: healthy,
            workers_suspect: suspect,
            workers_dead: dead_live + total.saturating_sub(alive),
            degraded: self.degraded.is_some(),
            halted: self.halted.is_some(),
        }
    }

    /// The structured recovery event log (shared with the supervisor
    /// thread and retry callbacks). Tests assert against
    /// [`EventLog::recent`]; operators point `DPMM_EVENT_LOG` at a file.
    pub fn events(&self) -> &Arc<EventLog> {
        &self.events
    }

    /// Act on the heartbeat registry's verdicts: proactively evict every
    /// worker currently rated [`Liveness::Dead`] and re-shard its window
    /// batches onto survivors — *before* any ingest or sweep trips over
    /// the corpse. Called at the top of every ingest and from the serving
    /// batcher's idle [`StreamFitter::tick`]; tests and embedding callers
    /// may call it directly. Returns the number of workers evicted. No-op
    /// (`Ok(0)`) when supervision is disabled or the stream is halted.
    pub fn poll_supervision(&mut self) -> Result<usize> {
        if self.halted.is_some() {
            return Ok(0);
        }
        let dead: Vec<usize> = match &self.supervisor {
            Some(sup) => sup
                .verdicts()
                .into_iter()
                .filter(|&(w, l)| l == Liveness::Dead && self.slots[w].conn.is_some())
                .map(|(w, _)| w)
                .collect(),
            None => return Ok(0),
        };
        if dead.is_empty() {
            return Ok(0);
        }
        for &w in &dead {
            self.events.emit(
                "evict_worker",
                vec![
                    ("worker", Json::from(w)),
                    ("addr", Json::from(self.slots[w].addr.as_str())),
                    ("reason", Json::from("heartbeat grace expired")),
                ],
            );
            self.fail_worker(w, "heartbeat grace expired (supervised eviction)");
        }
        self.recover_dead_workers()?;
        Ok(dead.len())
    }

    /// Freeze the current model into a serving snapshot.
    pub fn snapshot(&self) -> Result<ModelSnapshot> {
        ModelSnapshot::from_state(&self.state)
    }

    /// Close every worker's streaming session cleanly.
    pub fn shutdown(&mut self) -> Result<()> {
        for slot in self.slots.iter_mut() {
            if let Some(conn) = slot.conn.as_mut() {
                write_message(conn, &Message::Shutdown).ok();
                wire::read_message(conn).ok();
            }
        }
        Ok(())
    }

    // ---------- wire plumbing ----------

    fn request_to(&mut self, w: usize, msg: &Message) -> Result<Message> {
        let conn = self.slots[w]
            .conn
            .as_mut()
            .ok_or_else(|| anyhow!("worker {w} ({}) is down", self.slots[w].addr))?;
        request(conn, msg)
    }

    /// Least-loaded live worker (ties → lowest index); `Err` = none left.
    fn route_owner(&self) -> Result<usize> {
        self.route_owner_excluding(None)
    }

    /// [`Self::route_owner`] skipping one slot (the rebalance source —
    /// moving a batch "onto" its own source would strand it there).
    fn route_owner_excluding(&self, exclude: Option<usize>) -> Result<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(i, s)| s.conn.is_some() && Some(*i) != exclude)
            .min_by_key(|(i, s)| (s.points, *i))
            .map(|(i, _)| i)
            .ok_or_else(|| {
                anyhow!("no live workers remain (all {} failed)", self.slots.len())
            })
    }

    /// Mark a worker dead and latch the degraded reason. Idempotent.
    fn fail_worker(&mut self, w: usize, why: &str) {
        if self.slots[w].conn.take().is_some() {
            let msg = format!("worker {w} ({}) failed: {why}", self.slots[w].addr);
            eprintln!("dpmm stream: {msg}; re-sharding its batches onto survivors");
            self.events.emit(
                "worker_failed",
                vec![
                    ("worker", Json::from(w)),
                    ("addr", Json::from(self.slots[w].addr.as_str())),
                    ("reason", Json::from(why)),
                ],
            );
            if let Some(sup) = &self.supervisor {
                sup.retire(w);
            }
            if self.degraded.is_none() {
                self.degraded = Some(msg);
            }
        }
    }

    // ---------- recovery ----------

    /// Re-home every batch whose owner died: retire its mirror from `win`,
    /// then re-ingest the retained raw values onto survivors through the
    /// ordinary MAP-seed path (fresh leader-forked RNG stream — this is
    /// the one place unplanned churn forks the lineage; see
    /// docs/DETERMINISM.md). Survivors that fail mid-recovery are killed
    /// and the loop continues; `Err` only when no live worker remains —
    /// and that **latches `halted` here**, not only in the `ingest`
    /// wrapper: recovery also runs from the checkpoint/join/remove paths,
    /// and a failure there leaves the same inconsistent accumulators
    /// (mirrors retired, batches unhomed) that must gate future ingests.
    fn recover_dead_workers(&mut self) -> Result<()> {
        let result = self.recover_dead_workers_inner();
        if let Err(e) = &result {
            self.halt(&format!("{e:#}"));
        }
        result
    }

    /// Latch the terminal halt reason (first failure wins).
    fn halt(&mut self, why: &str) {
        if self.halted.is_none() {
            self.events.emit("halt", vec![("reason", Json::from(why))]);
            self.halted = Some(why.to_string());
        }
    }

    fn recover_dead_workers_inner(&mut self) -> Result<()> {
        loop {
            let pos = self
                .fifo
                .iter()
                .position(|r| self.slots[r.owner].conn.is_none());
            let Some(pos) = pos else { return Ok(()) };
            let mirror = std::mem::take(&mut self.fifo[pos].stats);
            if !mirror.is_empty() {
                for (kk, pair) in mirror.iter().enumerate() {
                    for h in 0..2 {
                        self.win[kk][h].try_unmerge(&pair[h]).map_err(|e| {
                            anyhow!("retiring dead worker's batch mirror: {e}")
                        })?;
                    }
                }
                sync_model_stats(&mut self.state, &self.base, &self.win);
            }
            let (old_owner, n) = (self.fifo[pos].owner, self.fifo[pos].n);
            self.slots[old_owner].points = self.slots[old_owner].points.saturating_sub(n);
            self.reingest_detached(pos)?;
        }
    }

    /// Re-ingest the (detached, mirror-retired) batch at FIFO position
    /// `pos` onto the least-loaded survivor, retrying across failures.
    fn reingest_detached(&mut self, pos: usize) -> Result<()> {
        loop {
            let owner = self.route_owner()?;
            let seed = self.rng.next_u64();
            let params = StepParams::map_snapshot(&self.state);
            let (id, n, x) = {
                let rec = &self.fifo[pos];
                (rec.id, rec.n, rec.x.clone())
            };
            let msg = Message::StreamIngest { batch_id: id, seed, params, x };
            match self.request_to(owner, &msg) {
                Ok(reply) => match self.accept_ingest_delta(reply, owner, id) {
                    Ok(added) => {
                        let rec = &mut self.fifo[pos];
                        rec.stats = added;
                        rec.owner = owner;
                        self.slots[owner].points += n;
                        sync_model_stats(&mut self.state, &self.base, &self.win);
                        self.events.emit(
                            "reingest",
                            vec![
                                ("batch", Json::from(id as usize)),
                                ("to", Json::from(owner)),
                                ("points", Json::from(n)),
                            ],
                        );
                        return Ok(());
                    }
                    Err(e) => self.fail_worker(owner, &format!("{e:#}")),
                },
                Err(e) => self.fail_worker(owner, &format!("{e:#}")),
            }
        }
    }

    /// Validate + fold one `StreamIngest` reply: exactly one delta for the
    /// expected batch, well-formed bundle; folds `added` into `win` and
    /// returns it (the caller installs it as the batch mirror). `Err`
    /// means the *worker's reply* was unusable (caller kills the worker);
    /// nothing is folded on error.
    fn accept_ingest_delta(
        &mut self,
        reply: Message,
        worker: usize,
        batch_id: u64,
    ) -> Result<Vec<[Stats; 2]>> {
        let deltas = match reply {
            Message::StatsDelta(ds) => ds,
            other => bail!("worker {worker}: expected StatsDelta, got {other:?}"),
        };
        let delta = match deltas.as_slice() {
            [d] if d.batch_id == batch_id => d,
            [d] => bail!("worker {worker}: delta for batch {}, want {batch_id}", d.batch_id),
            _ => bail!("worker {worker}: {} deltas for batch {batch_id}, want 1", deltas.len()),
        };
        if !delta.removed.is_empty() {
            bail!("worker {worker}: ingest delta for batch {batch_id} removes statistics");
        }
        check_bundle(&delta.added, self.k(), self.dim(), &self.state.prior, "added")?;
        if delta.added.is_empty() {
            bail!("worker {worker}: ingest delta for batch {batch_id} is empty");
        }
        for (kk, pair) in delta.added.iter().enumerate() {
            for h in 0..2 {
                self.win[kk][h]
                    .try_merge(&pair[h])
                    .map_err(|e| anyhow!("folding ingest delta: {e}"))?;
            }
        }
        Ok(delta.added.clone())
    }

    // ---------- elastic membership ----------

    /// Join a new worker to the live session and rebalance: whole batches
    /// move from overloaded workers onto the newcomer with labels and RNG
    /// streams intact, so the model trajectory is **bitwise-unchanged** by
    /// the join (pinned by `tests/integration_stream_recovery.rs`).
    pub fn join_worker(&mut self, addr: &str) -> Result<()> {
        if let Some(why) = &self.halted {
            bail!("stream is halted ({why}); cannot join workers");
        }
        let prior = self.state.prior.clone();
        let conn = open_session_retry(
            addr,
            &prior,
            self.cfg.worker_threads,
            kernel_byte(self.cfg.kernel),
            true,
            &mut self.retry,
            &self.events,
        )?;
        self.slots.push(WorkerSlot {
            addr: addr.to_string(),
            conn: Some(conn),
            points: 0,
            retired: false,
        });
        let new_idx = self.slots.len() - 1;
        if let Some(sup) = &self.supervisor {
            sup.register(addr);
        }
        self.events.emit(
            "join",
            vec![("worker", Json::from(new_idx)), ("addr", Json::from(addr))],
        );
        self.rebalance_onto(new_idx)?;
        self.recover_dead_workers()
    }

    /// Gracefully remove a live worker: its batches rebalance onto the
    /// remaining live workers (labels/RNG intact — no trajectory fork),
    /// the session closes, and the slot is marked retired (not degraded).
    pub fn remove_worker(&mut self, addr: &str) -> Result<()> {
        if let Some(why) = &self.halted {
            bail!("stream is halted ({why}); cannot remove workers");
        }
        let w = self
            .slots
            .iter()
            .position(|s| s.addr == addr && s.conn.is_some())
            .ok_or_else(|| anyhow!("no live worker at {addr}"))?;
        if self.workers_alive() <= 1 {
            bail!("cannot remove the last live worker");
        }
        let ids: Vec<u64> =
            self.fifo.iter().filter(|r| r.owner == w).map(|r| r.id).collect();
        for id in ids {
            self.move_batch(id, w, None)?;
        }
        // Mid-drain failures can bounce batches back onto the source (the
        // restore fallback of last resort). Shutting it down anyway would
        // force a lineage-forking recovery of those batches, so refuse:
        // the cluster stays consistent and the caller can retry.
        if self.fifo.iter().any(|r| r.owner == w) {
            bail!(
                "could not drain worker {addr}: other workers failed mid-rebalance and \
                 some batches remain resident; retry once the cluster is healthy"
            );
        }
        if let Some(conn) = self.slots[w].conn.as_mut() {
            write_message(conn, &Message::Shutdown).ok();
            wire::read_message(conn).ok();
        }
        self.slots[w].conn = None;
        self.slots[w].retired = true;
        if let Some(sup) = &self.supervisor {
            sup.retire(w);
        }
        self.events.emit(
            "remove",
            vec![("worker", Json::from(w)), ("addr", Json::from(addr))],
        );
        self.recover_dead_workers()
    }

    /// Move batches from overloaded workers onto `target` until it holds
    /// roughly its fair share (oldest-first, deterministic rule).
    fn rebalance_onto(&mut self, target: usize) -> Result<()> {
        let alive = self.workers_alive().max(1);
        let fair = self.window_points / alive;
        let ids: Vec<u64> = self.fifo.iter().map(|r| r.id).collect();
        for id in ids {
            if self.slots[target].conn.is_none() {
                break; // target died mid-rebalance; recovery handles it
            }
            let Some(pos) = self.fifo.binary_search_by_key(&id, |r| r.id).ok() else {
                continue;
            };
            let (owner, n) = (self.fifo[pos].owner, self.fifo[pos].n);
            if owner == target || self.slots[target].points + n > fair {
                continue;
            }
            if self.slots[owner].conn.is_none() || self.slots[owner].points <= fair {
                continue;
            }
            self.move_batch(id, owner, Some(target))?;
        }
        Ok(())
    }

    /// Move one batch from `source` to `prefer` (or the least-loaded other
    /// live worker): detach via `StreamRebalance`, re-install verbatim via
    /// `StreamRestore`. Worker failures along the way are killed and left
    /// for [`Self::recover_dead_workers`]; `Err` = no live workers remain.
    fn move_batch(&mut self, id: u64, source: usize, prefer: Option<usize>) -> Result<()> {
        let pos = self
            .fifo
            .binary_search_by_key(&id, |r| r.id)
            .map_err(|_| anyhow!("move of unknown batch {id}"))?;
        let n = self.fifo[pos].n;
        let state = match self.request_to(source, &Message::StreamRebalance { batch_ids: vec![id] }) {
            Ok(Message::StreamBatchStateReply(states)) => match states.into_iter().next() {
                Some(st) if st.batch_id == id && st.z.len() == n => st,
                _ => {
                    self.fail_worker(source, "malformed StreamRebalance reply");
                    return Ok(()); // recovery re-homes this batch
                }
            },
            Ok(other) => {
                self.fail_worker(source, &format!("unexpected StreamRebalance reply {other:?}"));
                return Ok(());
            }
            Err(e) => {
                self.fail_worker(source, &format!("{e:#}"));
                return Ok(());
            }
        };
        // Detached from the source; the leader now holds the only copy of
        // the labels/RNG. Install on the preferred target, falling back to
        // any other live worker, and — if every other worker is gone —
        // back onto the still-live source itself: re-installing there is
        // always valid and strictly better than stranding the batch
        // (a stranded batch would poison a later evict of it).
        self.slots[source].points = self.slots[source].points.saturating_sub(n);
        let k = self.k() as u32;
        let mut prefer = prefer;
        loop {
            let target = match prefer.filter(|&t| self.slots[t].conn.is_some()) {
                Some(t) => t,
                None => match self.route_owner_excluding(Some(source)) {
                    Ok(t) => t,
                    Err(_) if self.slots[source].conn.is_some() => source,
                    Err(e) => {
                        // Detached batch + no live worker anywhere: the
                        // labels/RNG just left with the sockets. Halt —
                        // this state must gate every future ingest.
                        self.halt(&format!("{e:#}"));
                        return Err(e);
                    }
                },
            };
            prefer = None;
            let msg = Message::StreamRestore {
                batch_id: id,
                k,
                x: self.fifo[pos].x.clone(),
                z: state.z.clone(),
                zsub: state.zsub.clone(),
                rng: state.rng,
            };
            match self.request_to(target, &msg) {
                Ok(Message::Ack) => {
                    self.fifo[pos].owner = target;
                    self.slots[target].points += n;
                    self.events.emit(
                        "rebalance",
                        vec![
                            ("batch", Json::from(id as usize)),
                            ("from", Json::from(source)),
                            ("to", Json::from(target)),
                            ("points", Json::from(n)),
                        ],
                    );
                    return Ok(());
                }
                Ok(other) => {
                    self.fail_worker(target, &format!("unexpected StreamRestore reply {other:?}"))
                }
                Err(e) => self.fail_worker(target, &format!("{e:#}")),
            }
        }
    }

    // ---------- checkpointing ----------

    /// Write a durable leader checkpoint (atomic temp-file + rename):
    /// model, accumulators, RNG lineage, routing table, and every windowed
    /// batch's values + labels + per-batch RNG streams (collected from the
    /// workers via `StreamBatchState`). `&mut self` because a worker found
    /// dead during collection is failed + recovered like any other op.
    pub fn save_stream_checkpoint(&mut self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(why) = &self.halted {
            bail!("stream is halted ({why}); refusing to checkpoint inconsistent state");
        }
        let mut states: HashMap<u64, BatchState> = HashMap::new();
        let mut attempts = 0;
        'gather: loop {
            attempts += 1;
            if attempts > self.slots.len() + 1 {
                bail!("could not collect worker batch state for the checkpoint");
            }
            states.clear();
            let workers: Vec<usize> = (0..self.slots.len())
                .filter(|&w| self.slots[w].conn.is_some())
                .collect();
            for w in workers {
                let ids: Vec<u64> =
                    self.fifo.iter().filter(|r| r.owner == w).map(|r| r.id).collect();
                if ids.is_empty() {
                    continue;
                }
                let want: HashSet<u64> = ids.iter().copied().collect();
                match self.request_to(w, &Message::StreamBatchState { batch_ids: ids }) {
                    Ok(Message::StreamBatchStateReply(ss)) => {
                        let got: HashSet<u64> = ss.iter().map(|s| s.batch_id).collect();
                        if got != want {
                            self.fail_worker(w, "StreamBatchState reply named wrong batches");
                            self.recover_dead_workers()?;
                            continue 'gather;
                        }
                        for st in ss {
                            states.insert(st.batch_id, st);
                        }
                    }
                    Ok(other) => {
                        self.fail_worker(w, &format!("unexpected StreamBatchState reply {other:?}"));
                        self.recover_dead_workers()?;
                        continue 'gather;
                    }
                    Err(e) => {
                        self.fail_worker(w, &format!("{e:#}"));
                        self.recover_dead_workers()?;
                        continue 'gather;
                    }
                }
            }
            break;
        }
        let mut batches = Vec::with_capacity(self.fifo.len());
        for rec in &self.fifo {
            let st = states
                .remove(&rec.id)
                .ok_or_else(|| anyhow!("no worker state collected for batch {}", rec.id))?;
            if st.z.len() != rec.n {
                bail!(
                    "worker reported {} labels for batch {} of {} points",
                    st.z.len(),
                    rec.id,
                    rec.n
                );
            }
            batches.push(BatchDump {
                id: rec.id,
                x: rec.x.clone(),
                z: st.z,
                zsub: st.zsub,
                rng: st.rng,
            });
        }
        save_stream_checkpoint(
            path,
            &StreamSave {
                state: &self.state,
                rng: self.rng.state(),
                ingested: self.ingested,
                next_batch_id: self.next_batch_id,
                window: self.cfg.window,
                sweeps: self.cfg.sweeps,
                decay: self.cfg.decay,
                alpha: self.cfg.alpha,
                base: &self.base,
                win: &self.win,
                contents: WindowContents::Distributed { batches },
            },
        )
    }

    // ---------- ingest ----------

    /// Fold one row-major mini-batch through the cluster: route → seed →
    /// fold → evict → sweeps (see the module docs). Worker failures are
    /// absorbed: the dead worker's batches re-shard onto survivors and the
    /// ingest completes (degraded mode surfaces through [`Self::health`]).
    /// Only an unrecoverable failure — no live workers left, or a
    /// leader-side fold invariant breaking — errors, and then the fitter
    /// **halts**: every further ingest fails fast, because continuing
    /// could fold statistics the workers never agreed on. Batch-validation
    /// errors (shape, non-finite values) happen before any wire traffic
    /// and neither halt nor degrade.
    pub fn ingest(&mut self, batch: &[f64]) -> Result<IngestSummary> {
        if let Some(why) = &self.halted {
            bail!(
                "distributed stream halted ({why}); resume from the last checkpoint \
                 with --resume, or restart the stream leader from a snapshot"
            );
        }
        // Act on heartbeat verdicts first: a worker the supervisor already
        // declared dead is evicted before this ingest routes anything at
        // it (proactive, instead of burning a send + I/O timeout on it).
        self.poll_supervision()?;
        let d = self.dim();
        if batch.len() % d != 0 {
            bail!(
                "ingest batch length {} is not a multiple of the model dimension {d}",
                batch.len()
            );
        }
        if batch.iter().any(|v| !v.is_finite()) {
            bail!("ingest batch contains non-finite values");
        }
        let n = batch.len() / d;
        if n == 0 {
            return Ok(IngestSummary {
                accepted: 0,
                window: self.window_points,
                evicted: 0,
                k: self.k(),
            });
        }
        let result = self.ingest_elastic(batch, n, d);
        if let Err(e) = &result {
            self.halt(&format!("{e:#}"));
        }
        if result.is_ok() {
            crate::telemetry::catalog::ingest_points_total().add(n as u64);
        }
        result
    }

    /// The worker-facing body of [`Self::ingest`] (the wrapper owns
    /// validation and halting).
    fn ingest_elastic(&mut self, batch: &[f64], n: usize, d: usize) -> Result<IngestSummary> {
        // 1. Exponential forgetting on the frozen base (leader-side only —
        // workers hold points and labels, never evidence accumulators).
        if self.cfg.decay < 1.0 {
            for b in self.base.iter_mut() {
                b[0].decay(self.cfg.decay);
                b[1].decay(self.cfg.decay);
            }
            sync_model_stats(&mut self.state, &self.base, &self.win);
        }

        // 2. Route to the least-loaded live worker; on failure, kill the
        // worker, recover its resident batches, and retry on a survivor.
        let batch_id = self.next_batch_id;
        self.next_batch_id += 1;
        loop {
            let owner = self.route_owner()?;
            let seed = self.rng.next_u64();
            let params = StepParams::map_snapshot(&self.state);
            let msg = Message::StreamIngest { batch_id, seed, params, x: batch.to_vec() };
            match self.request_to(owner, &msg) {
                Ok(reply) => match self.accept_ingest_delta(reply, owner, batch_id) {
                    Ok(added) => {
                        // Reclaim the wire message's point buffer as the
                        // retained durability copy — the happy path pays
                        // exactly one O(n·d) copy of the batch.
                        let Message::StreamIngest { x, .. } = msg else { unreachable!() };
                        self.fifo.push_back(BatchRec {
                            id: batch_id,
                            owner,
                            n,
                            x,
                            stats: added,
                        });
                        self.slots[owner].points += n;
                        self.window_points += n;
                        break;
                    }
                    Err(e) => {
                        self.fail_worker(owner, &format!("{e:#}"));
                        self.recover_dead_workers()?;
                    }
                },
                Err(e) => {
                    self.fail_worker(owner, &format!("{e:#}"));
                    self.recover_dead_workers()?;
                }
            }
        }
        sync_model_stats(&mut self.state, &self.base, &self.win);

        // 3. Leader-decided batch-granular eviction in global FIFO order:
        // the owning worker reports the batch's current grouped statistics,
        // which move from the window accumulators into the frozen base. On
        // a failure the owner is killed, the batch re-homes to a survivor
        // (MAP re-seeded), and the evict retries there.
        let mut evicted = 0usize;
        while self.window_points > self.cfg.window.max(1) {
            let (id, owner, bn) = {
                let rec = self.fifo.front().expect("window overflow with an empty FIFO");
                (rec.id, rec.owner, rec.n)
            };
            let reply = match self.request_to(owner, &Message::StreamEvict { batch_ids: vec![id] }) {
                Ok(reply) => reply,
                Err(e) => {
                    self.fail_worker(owner, &format!("{e:#}"));
                    self.recover_dead_workers()?;
                    continue;
                }
            };
            match self.accept_evict_stats(reply, owner, id) {
                Ok(()) => {
                    self.fifo.pop_front();
                    self.slots[owner].points = self.slots[owner].points.saturating_sub(bn);
                    self.window_points -= bn;
                    evicted += bn;
                }
                Err(e) => {
                    self.fail_worker(owner, &format!("{e:#}"));
                    self.recover_dead_workers()?;
                }
            }
        }
        sync_model_stats(&mut self.state, &self.base, &self.win);

        // 4. Restricted sweeps: leader samples steps (a)–(d), workers run
        // (e)/(f) over their window slices, leader folds the per-batch
        // deltas in ascending global batch id order. A worker lost
        // mid-sweep contributes no deltas this sweep; its batches are
        // re-sharded before the next one.
        let opts = SamplerOptions { sub_restart_every: 0, ..SamplerOptions::default() };
        for _ in 0..self.cfg.sweeps {
            if self.window_points == 0 {
                break;
            }
            sample_weights(&mut self.state, &mut self.rng);
            sample_sub_weights(&mut self.state, &mut self.rng);
            sample_params(&mut self.state, &opts, &mut self.rng);
            self.sweep_once()?;
        }

        self.ingested += n as u64;
        self.state.n_total += n;

        // 5. Periodic durable checkpoint. Best-effort on the periodic
        // path: an unwritable path must not kill a healthy stream (an
        // explicit save_stream_checkpoint call still errors loudly).
        self.batches_since_ckpt += 1;
        if let Some(ck) = self.cfg.checkpoint.clone() {
            if ck.every_batches > 0 && self.batches_since_ckpt >= ck.every_batches {
                self.batches_since_ckpt = 0;
                if let Err(e) = self.save_stream_checkpoint(&ck.path) {
                    eprintln!("dpmm stream: warning: periodic checkpoint failed: {e:#}");
                }
            }
        }

        Ok(IngestSummary {
            accepted: n,
            window: self.window_points,
            evicted,
            k: self.k(),
        })
    }

    /// One broadcast sweep: write `StreamSweep` to every live worker,
    /// collect + validate per-worker deltas (garbage kills the sender),
    /// fold in ascending batch-id order, then recover any casualties.
    fn sweep_once(&mut self) -> Result<()> {
        let msg = Message::StreamSweep(StepParams::snapshot(&self.state));
        let alive: Vec<usize> = (0..self.slots.len())
            .filter(|&w| self.slots[w].conn.is_some())
            .collect();
        let mut written = Vec::with_capacity(alive.len());
        let mut casualties: Vec<(usize, String)> = Vec::new();
        for &w in &alive {
            let conn = self.slots[w].conn.as_mut().expect("alive slot");
            match write_message(conn, &msg) {
                Ok(()) => written.push(w),
                Err(e) => casualties.push((w, format!("{e:#}"))),
            }
        }
        let mut results: Vec<(usize, Vec<BatchDelta>)> = Vec::new();
        for &w in &written {
            let conn = self.slots[w].conn.as_mut().expect("alive slot");
            match wire::read_message(conn) {
                Ok(Message::StatsDelta(ds)) => results.push((w, ds)),
                Ok(Message::Error(e)) => casualties.push((w, format!("worker error: {e}"))),
                Ok(other) => casualties.push((w, format!("unexpected sweep reply {other:?}"))),
                Err(e) => casualties.push((w, format!("{e:#}"))),
            }
        }
        for (w, why) in &casualties {
            self.fail_worker(*w, why);
        }
        let mut all: Vec<BatchDelta> = Vec::new();
        for (w, ds) in results {
            match self.validate_worker_deltas(w, &ds) {
                Ok(()) => all.extend(ds),
                Err(e) => self.fail_worker(w, &format!("{e:#}")),
            }
        }
        // Canonical fold order: ascending global batch id — identical no
        // matter how batches are partitioned across workers.
        let watch = crate::telemetry::Stopwatch::start();
        all.sort_by_key(|dlt| dlt.batch_id);
        for dlt in &all {
            self.apply_sweep_delta(dlt)?;
        }
        if !all.is_empty() {
            sync_model_stats(&mut self.state, &self.base, &self.win);
        }
        watch.observe(crate::telemetry::catalog::delta_fold_seconds());
        self.recover_dead_workers()
    }

    /// Every delta from worker `w` must name a batch that is resident,
    /// owned by `w`, named once, with well-formed bundles — anything else
    /// means the worker is confused or the frame was corrupt, and folding
    /// it blindly would corrupt the accumulators with no error.
    fn validate_worker_deltas(&self, w: usize, ds: &[BatchDelta]) -> Result<()> {
        let k = self.k();
        let d = self.dim();
        let mut seen = HashSet::with_capacity(ds.len());
        for dlt in ds {
            let pos = self
                .fifo
                .binary_search_by_key(&dlt.batch_id, |r| r.id)
                .map_err(|_| anyhow!("sweep delta for unknown batch {}", dlt.batch_id))?;
            if self.fifo[pos].owner != w {
                bail!("worker {w} sent a delta for batch {} it does not own", dlt.batch_id);
            }
            if !seen.insert(dlt.batch_id) {
                bail!("duplicate sweep delta for batch {}", dlt.batch_id);
            }
            check_bundle(&dlt.removed, k, d, &self.state.prior, "removed")?;
            check_bundle(&dlt.added, k, d, &self.state.prior, "added")?;
        }
        Ok(())
    }

    /// `win -= removed; win += added`, and the same on the batch's mirror
    /// so recovery always knows the batch's net contribution.
    fn apply_sweep_delta(&mut self, dlt: &BatchDelta) -> Result<()> {
        for (kk, pair) in dlt.removed.iter().enumerate() {
            for h in 0..2 {
                self.win[kk][h].try_unmerge(&pair[h])?;
            }
        }
        for (kk, pair) in dlt.added.iter().enumerate() {
            for h in 0..2 {
                self.win[kk][h].try_merge(&pair[h])?;
            }
        }
        let pos = self
            .fifo
            .binary_search_by_key(&dlt.batch_id, |r| r.id)
            .expect("delta validated as resident");
        let rec = &mut self.fifo[pos];
        for (kk, pair) in dlt.removed.iter().enumerate() {
            for h in 0..2 {
                rec.stats[kk][h].try_unmerge(&pair[h])?;
            }
        }
        for (kk, pair) in dlt.added.iter().enumerate() {
            for h in 0..2 {
                rec.stats[kk][h].try_merge(&pair[h])?;
            }
        }
        Ok(())
    }

    /// Validate + fold one `StreamEvict` reply: the reported statistics
    /// move from `win` into the frozen `base`. Nothing is folded on `Err`.
    fn accept_evict_stats(&mut self, reply: Message, worker: usize, id: u64) -> Result<()> {
        let deltas = match reply {
            Message::StatsDelta(ds) => ds,
            other => bail!("worker {worker}: expected StatsDelta, got {other:?}"),
        };
        let delta = match deltas.as_slice() {
            [d] if d.batch_id == id => d,
            _ => bail!("worker {worker}: malformed evict reply for batch {id}"),
        };
        // An empty bundle would pop the batch while leaving its evidence
        // stranded in `win` — silent mass loss, so it kills the worker.
        if delta.added.is_empty() {
            bail!("worker {worker}: evict reply for batch {id} carries no statistics");
        }
        check_bundle(&delta.added, self.k(), self.dim(), &self.state.prior, "evict")?;
        for (kk, pair) in delta.added.iter().enumerate() {
            for h in 0..2 {
                self.win[kk][h].try_unmerge(&pair[h])?;
                self.base[kk][h].try_merge(&pair[h])?;
            }
        }
        Ok(())
    }
}

impl Drop for DistributedFitter {
    fn drop(&mut self) {
        self.shutdown().ok();
    }
}

impl StreamFitter for DistributedFitter {
    fn dim(&self) -> usize {
        DistributedFitter::dim(self)
    }
    fn k(&self) -> usize {
        DistributedFitter::k(self)
    }
    fn ingest(&mut self, batch: &[f64]) -> Result<IngestSummary> {
        DistributedFitter::ingest(self, batch)
    }
    fn snapshot(&self) -> Result<ModelSnapshot> {
        DistributedFitter::snapshot(self)
    }
    fn ingested(&self) -> u64 {
        DistributedFitter::ingested(self)
    }
    fn health(&self) -> StreamHealth {
        DistributedFitter::health(self)
    }
    fn tick(&mut self) -> Result<()> {
        DistributedFitter::poll_supervision(self).map(|_| ())
    }
}

/// A wire-decoded stats bundle must be empty or exactly K entries of the
/// model's family and dimensionality (`try_merge` checks families but zips
/// over dimensions, so a corrupt width must be rejected before folding).
fn check_bundle(
    bundle: &[[Stats; 2]],
    k: usize,
    d: usize,
    prior: &Prior,
    what: &str,
) -> Result<()> {
    if bundle.is_empty() {
        return Ok(());
    }
    if bundle.len() != k {
        bail!("worker returned {} `{what}` clusters, want {k}", bundle.len());
    }
    for (kk, pair) in bundle.iter().enumerate() {
        for s in pair {
            if s.family() != prior.family() {
                bail!(
                    "worker `{what}` stats for cluster {kk} have family {}, want {}",
                    s.family(),
                    prior.family()
                );
            }
            if s.dim() != d {
                bail!(
                    "worker `{what}` stats for cluster {kk} have dimension {}, want {d}",
                    s.dim()
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::distributed::worker::spawn_local;
    use crate::serve::ModelSnapshot;
    use crate::stats::{NiwPrior, Prior};

    /// A tiny two-blob snapshot (mirrors the local fitter's test seed).
    fn seed_snapshot() -> ModelSnapshot {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let mut state = DpmmState::new(1.0, prior.clone(), 2, 200, &mut rng);
        for (k, center) in [(0usize, -6.0f64), (1, 6.0)] {
            let mut s = prior.empty_stats();
            for i in 0..100 {
                s.add(&[center + 0.03 * (i % 9) as f64, 0.05 * (i % 7) as f64 - 0.15]);
            }
            state.clusters[k].stats = s;
        }
        ModelSnapshot::from_state(&state).unwrap()
    }

    fn blob_batch(center: f64, n: usize, phase: usize) -> Vec<f64> {
        let mut v = Vec::with_capacity(n * 2);
        for i in 0..n {
            v.push(center + 0.04 * ((i + phase) % 11) as f64 - 0.2);
            v.push(0.03 * ((i * 3 + phase) % 5) as f64);
        }
        v
    }

    fn cluster_fitter(workers: usize, window: usize) -> DistributedFitter {
        let snap = seed_snapshot();
        let addrs: Vec<String> = (0..workers).map(|_| spawn_local().unwrap()).collect();
        DistributedFitter::from_snapshot(
            &snap,
            DistributedStreamConfig {
                workers: addrs,
                worker_threads: 2,
                window,
                sweeps: 2,
                alpha: 2.0,
                seed: 9,
                ..DistributedStreamConfig::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn distributed_ingest_tracks_blob_masses() {
        let mut f = cluster_fitter(2, 4096);
        let before = f.counts();
        f.ingest(&blob_batch(-6.0, 30, 0)).unwrap();
        let s = f.ingest(&blob_batch(6.0, 30, 1)).unwrap();
        assert_eq!(s.accepted, 30);
        assert_eq!(s.window, 60);
        assert_eq!(s.evicted, 0);
        assert_eq!(s.k, 2);
        let after = f.counts();
        assert!((after[0] - before[0] - 30.0).abs() < 1e-6, "{before:?} -> {after:?}");
        assert!((after[1] - before[1] - 30.0).abs() < 1e-6);
        assert_eq!(f.ingested(), 60);
        assert!(f.snapshot().is_ok());
        let h = f.health();
        assert_eq!((h.workers_total, h.workers_alive), (2, 2));
        assert!(!h.degraded && !h.halted);
        f.shutdown().unwrap();
    }

    #[test]
    fn eviction_preserves_total_mass() {
        // window = 64 < 4 × 30 ingested: whole batches retire in FIFO
        // order, and the evidence stays in the model.
        let mut f = cluster_fitter(2, 64);
        let mut evicted = 0;
        for phase in 0..4 {
            evicted += f.ingest(&blob_batch(-6.0, 30, phase)).unwrap().evicted;
        }
        assert!(evicted > 0, "window 64 must have overflowed");
        assert!(f.window_len() <= 64);
        assert_eq!(f.window_len() + evicted, 120);
        let total: f64 = f.counts().iter().sum();
        assert!((total - 200.0 - 120.0).abs() < 1e-6, "total mass {total}");
    }

    #[test]
    fn rejects_bad_batches_and_configs() {
        let mut f = cluster_fitter(1, 128);
        assert!(f.ingest(&[1.0, 2.0, 3.0]).is_err()); // not a multiple of d
        assert!(f.ingest(&[f64::NAN, 0.0]).is_err());
        // Validation failures must not halt the stream.
        assert!(!f.health().halted);
        let s = f.ingest(&[]).unwrap();
        assert_eq!(s.accepted, 0);
        let snap = seed_snapshot();
        assert!(DistributedFitter::from_snapshot(
            &snap,
            DistributedStreamConfig::default() // no workers
        )
        .is_err());
        assert!(DistributedFitter::from_snapshot(
            &snap,
            DistributedStreamConfig {
                workers: vec![spawn_local().unwrap()],
                decay: 0.0,
                ..DistributedStreamConfig::default()
            }
        )
        .is_err());
    }

    #[test]
    fn least_loaded_routing_balances_workers() {
        let mut f = cluster_fitter(2, 1 << 20);
        for phase in 0..6 {
            f.ingest(&blob_batch(-6.0, 20, phase)).unwrap();
        }
        // Equal batch sizes ⇒ strict alternation ⇒ a 60/60 split.
        assert_eq!(f.worker_points(), vec![60, 60]);
    }

    #[test]
    fn graceful_remove_rebalances_without_degrading() {
        let mut f = cluster_fitter(2, 1 << 20);
        for phase in 0..4 {
            f.ingest(&blob_batch(-6.0, 20, phase)).unwrap();
        }
        let victim = f.slots[1].addr.clone();
        f.remove_worker(&victim).unwrap();
        let h = f.health();
        assert_eq!((h.workers_total, h.workers_alive), (1, 1));
        assert!(!h.degraded, "graceful leave must not report degraded");
        assert_eq!(f.worker_points(), vec![80, 0]);
        // The survivor keeps ingesting.
        f.ingest(&blob_batch(6.0, 20, 9)).unwrap();
        assert_eq!(f.window_len(), 100);
        assert!(f.remove_worker(&f.slots[0].addr.clone()).is_err(), "last worker");
    }
}
