//! Distributed streaming ingest: the leader side of `dpmm stream
//! --workers=host:port,...`.
//!
//! The local [`IncrementalFitter`](super::IncrementalFitter) caps ingest
//! throughput and window size at one machine's cores and RAM. This module
//! shards the stream across the same TCP workers the batch backend uses
//! (`dpmm worker`): the leader routes each ingest mini-batch to the
//! least-loaded worker's window slice, workers MAP-seed and resweep their
//! slices locally, and only **grouped sufficient-statistics deltas**
//! ([`BatchDelta`]) cross the wire — O(K·d²) per changed batch per sweep,
//! never O(N·d), the paper's low-bandwidth distribution property carried
//! over to streaming.
//!
//! # Division of labor
//!
//! The **leader** ([`DistributedFitter`]) owns exactly what the local
//! fitter's coordinator half owns: the model state, the frozen `base` and
//! windowed `win` accumulators, the single RNG that samples weights and
//! parameters, and — new here — the **global batch FIFO** that decides
//! eviction. Per ingested batch it runs the same five phases as the local
//! fitter (decay → seed → fold → evict → `sweeps` restricted sweeps), but
//! phases 2 and 5 execute worker-side:
//!
//! * **Ingest**: the leader picks the least-loaded worker (fewest windowed
//!   points, ties → lowest index), assigns the batch a global id and a
//!   forked RNG seed, and ships it with a deterministic MAP parameter
//!   snapshot ([`StepParams::map_snapshot`]). The worker seeds labels,
//!   appends the batch to its window slice, and returns the batch's
//!   grouped stats delta.
//! * **Evict**: when the global window overflows, the leader retires whole
//!   batches in global FIFO order ([`Message::StreamEvict`]); the owning
//!   worker returns the batch's current grouped statistics, which the
//!   leader moves from `win` into `base` (labels freeze as-is). Eviction
//!   is batch-granular: the window occupancy may dip below the capacity by
//!   up to one batch, but the eviction *sequence* is partition-independent.
//! * **Sweep**: per restricted sweep the leader samples weights/parameters
//!   (steps (a)–(d)) exactly like the local fitter and broadcasts one
//!   [`StepParams`]; every worker reruns the assignment kernels over its
//!   resident batches (one shard per batch, each with its persistent RNG
//!   stream) and replies with per-batch deltas of the moved points.
//!
//! # Determinism across worker counts
//!
//! A fixed-seed ingest history yields **bitwise-identical** leader-side
//! statistics for any worker count (and tiled vs scalar kernels), because
//! nothing observable depends on *which* worker owns a batch:
//!
//! * each batch's sweep RNG is seeded by the leader in global batch order
//!   and lives with the batch, so label trajectories depend only on the
//!   batch's values, its seed, and the broadcast plans;
//! * per-point assignment given a plan is conditionally independent (the
//!   restricted sweep interacts only through statistics → next plan), so
//!   co-residency of batches on a worker never affects labels;
//! * all statistics folds happen leader-side through one canonical path:
//!   per-batch deltas (each computed by the worker's single-threaded
//!   grouped [`fold_groups`](super::fitter) fold over that batch alone)
//!   are applied in **ascending global batch id order**, and eviction
//!   order is the leader's global FIFO.
//!
//! `tests/integration_stream_distributed.rs` pins the 1-vs-2-worker and
//! tiled-vs-scalar bitwise contracts end-to-end.

use super::fitter::{
    seed_state_from_snapshot, sync_model_stats, IngestSummary, StreamFitter,
};
use crate::backend::distributed::wire::{
    self, request, write_message, BatchDelta, Message,
};
use crate::backend::shard::AssignKernel;
use crate::model::DpmmState;
use crate::rng::{Rng, Xoshiro256pp};
use crate::sampler::{
    sample_params, sample_sub_weights, sample_weights, SamplerOptions, StepParams,
};
use crate::serve::ModelSnapshot;
use crate::stats::Stats;
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::net::TcpStream;

/// Distributed streaming knobs (the leader-side analog of
/// [`super::StreamConfig`]; per-worker thread/kernel execution is
/// configured at `StreamInit` instead of per-sweep).
#[derive(Debug, Clone)]
pub struct DistributedStreamConfig {
    /// Worker addresses (`host:port`), each running `dpmm worker`.
    pub workers: Vec<String>,
    /// Sweep threads per worker process.
    pub worker_threads: usize,
    /// Global sliding-window capacity in points (across all workers).
    /// Eviction is batch-granular in global FIFO order.
    pub window: usize,
    /// Restricted-Gibbs sweeps over the window per ingested batch.
    pub sweeps: usize,
    /// Exponential forgetting factor applied to the frozen base per ingest.
    pub decay: f64,
    /// DP concentration for the restricted sweeps.
    pub alpha: f64,
    /// RNG seed for the leader's weight/parameter draws and the per-batch
    /// sweep-stream forks.
    pub seed: u64,
    /// Assignment kernel shipped to every worker (`None` = each worker's
    /// own `DPMM_ASSIGN_KERNEL` environment decides).
    pub kernel: Option<AssignKernel>,
}

impl Default for DistributedStreamConfig {
    fn default() -> Self {
        Self {
            workers: Vec::new(),
            worker_threads: 1,
            window: 32 * 1024,
            sweeps: 2,
            decay: 1.0,
            alpha: 10.0,
            seed: 0,
            kernel: None,
        }
    }
}

/// One windowed batch in the leader's global FIFO.
#[derive(Debug, Clone, Copy)]
struct BatchRec {
    id: u64,
    owner: usize,
    n: usize,
}

/// Leader of a distributed streaming cluster: implements the same
/// [`StreamFitter`] surface as the local fitter, with sweeps executed by
/// TCP workers (see the module docs).
pub struct DistributedFitter {
    state: DpmmState,
    /// Frozen evidence per (cluster, sub): seed snapshot + everything
    /// evicted from the window.
    base: Vec<[Stats; 2]>,
    /// The distributed window's live contribution per (cluster, sub) —
    /// maintained exclusively by the leader's canonical delta folds.
    win: Vec<[Stats; 2]>,
    conns: Vec<TcpStream>,
    /// Windowed batches, oldest first (global ingest order).
    fifo: VecDeque<BatchRec>,
    /// Windowed points per worker (the routing load measure).
    worker_points: Vec<usize>,
    window_points: usize,
    next_batch_id: u64,
    rng: Xoshiro256pp,
    cfg: DistributedStreamConfig,
    ingested: u64,
    /// Set when a mid-protocol failure may have left worker window state
    /// (labels, resident batches, RNG streams) diverged from the leader's
    /// accumulators. Once poisoned, every further ingest fails fast with
    /// this reason — silently resuming would fold deltas against stats the
    /// leader never saw and corrupt the model without any error. The
    /// serving layer keeps answering predicts from the last published
    /// snapshot throughout; recovery is restarting the stream leader
    /// (which re-seeds every worker window from a fresh snapshot).
    poisoned: Option<String>,
}

impl DistributedFitter {
    /// Connect to the workers, open a streaming session on each, and seed
    /// the leader model from a frozen snapshot (the same seeding path as
    /// the local fitter, so fixed-seed histories start bitwise-identical).
    pub fn from_snapshot(
        snap: &ModelSnapshot,
        cfg: DistributedStreamConfig,
    ) -> Result<DistributedFitter> {
        if cfg.workers.is_empty() {
            bail!("distributed streaming needs at least one worker address (--workers=host:port,...)");
        }
        if !(cfg.decay > 0.0 && cfg.decay <= 1.0) {
            bail!("stream decay must be in (0, 1], got {}", cfg.decay);
        }
        if !(cfg.alpha > 0.0) {
            bail!("stream alpha must be positive, got {}", cfg.alpha);
        }
        let (state, base) = seed_state_from_snapshot(snap, cfg.alpha)?;
        let k = state.k();
        let prior = state.prior.clone();
        let win: Vec<[Stats; 2]> =
            (0..k).map(|_| [prior.empty_stats(), prior.empty_stats()]).collect();
        let kernel_byte = match cfg.kernel {
            None => 0u8,
            Some(AssignKernel::Tiled) => 1,
            Some(AssignKernel::Scalar) => 2,
        };
        let mut conns = Vec::with_capacity(cfg.workers.len());
        for addr in &cfg.workers {
            let mut stream = TcpStream::connect(addr)
                .with_context(|| format!("connecting to stream worker {addr}"))?;
            wire::configure_stream(&stream)
                .with_context(|| format!("configuring socket to stream worker {addr}"))?;
            let init = Message::StreamInit {
                d: prior.dim() as u32,
                prior: prior.clone(),
                threads: cfg.worker_threads.max(1) as u32,
                kernel: kernel_byte,
            };
            match request(&mut stream, &init)? {
                Message::Ack => {}
                other => bail!("worker {addr} StreamInit reply: {other:?}"),
            }
            conns.push(stream);
        }
        let w = conns.len();
        Ok(DistributedFitter {
            state,
            base,
            win,
            conns,
            fifo: VecDeque::new(),
            worker_points: vec![0; w],
            window_points: 0,
            next_batch_id: 0,
            rng: Xoshiro256pp::seed_from_u64(cfg.seed),
            cfg,
            ingested: 0,
            poisoned: None,
        })
    }

    pub fn k(&self) -> usize {
        self.state.k()
    }

    pub fn dim(&self) -> usize {
        self.state.prior.dim()
    }

    pub fn num_workers(&self) -> usize {
        self.conns.len()
    }

    /// Points ingested over the fitter's lifetime.
    pub fn ingested(&self) -> u64 {
        self.ingested
    }

    /// Points currently resweepable across all worker window slices.
    pub fn window_len(&self) -> usize {
        self.window_points
    }

    /// Per-cluster point masses (base + window evidence).
    pub fn counts(&self) -> Vec<f64> {
        self.state.counts()
    }

    pub fn state(&self) -> &DpmmState {
        &self.state
    }

    /// Freeze the current model into a serving snapshot.
    pub fn snapshot(&self) -> Result<ModelSnapshot> {
        ModelSnapshot::from_state(&self.state)
    }

    /// Close every worker's streaming session cleanly.
    pub fn shutdown(&mut self) -> Result<()> {
        for conn in self.conns.iter_mut() {
            write_message(conn, &Message::Shutdown).ok();
            wire::read_message(conn).ok();
        }
        Ok(())
    }

    /// Fold one row-major mini-batch through the cluster: route → seed →
    /// fold → evict → sweeps (see the module docs). A worker failure
    /// surfaces as an error; the caller (the serving batcher) keeps the
    /// previous published snapshot live in that case, and the fitter
    /// **poisons itself** — worker windows may have committed state the
    /// leader never folded, so resuming ingest would silently corrupt the
    /// statistics. Batch-validation errors (shape, non-finite values)
    /// happen before any wire traffic and do not poison.
    pub fn ingest(&mut self, batch: &[f64]) -> Result<IngestSummary> {
        if let Some(why) = &self.poisoned {
            bail!(
                "distributed stream halted by an earlier mid-ingest worker failure \
                 ({why}); restart the stream leader to re-seed the worker windows"
            );
        }
        let d = self.dim();
        if batch.len() % d != 0 {
            bail!(
                "ingest batch length {} is not a multiple of the model dimension {d}",
                batch.len()
            );
        }
        if batch.iter().any(|v| !v.is_finite()) {
            bail!("ingest batch contains non-finite values");
        }
        let n = batch.len() / d;
        if n == 0 {
            return Ok(IngestSummary {
                accepted: 0,
                window: self.window_points,
                evicted: 0,
                k: self.k(),
            });
        }
        // Everything past this point talks to workers; any failure may
        // leave remote window state the leader did not account for.
        let result = self.ingest_wire(batch, n, d);
        if let Err(e) = &result {
            self.poisoned = Some(format!("{e:#}"));
        }
        result
    }

    /// The wire-touching body of [`Self::ingest`] (see its docs; the
    /// wrapper owns validation and poisoning).
    fn ingest_wire(&mut self, batch: &[f64], n: usize, d: usize) -> Result<IngestSummary> {
        // 1. Exponential forgetting on the frozen base (leader-side only —
        // workers hold points and labels, never evidence accumulators).
        if self.cfg.decay < 1.0 {
            for b in self.base.iter_mut() {
                b[0].decay(self.cfg.decay);
                b[1].decay(self.cfg.decay);
            }
            sync_model_stats(&mut self.state, &self.base, &self.win);
        }

        // 2. Route to the least-loaded worker (ties → lowest index).
        // Ownership decides only *where* the batch lives; the model
        // trajectory is ownership-independent (see the module docs).
        let owner = (0..self.worker_points.len())
            .min_by_key(|&i| self.worker_points[i])
            .expect("at least one worker");
        let batch_id = self.next_batch_id;
        self.next_batch_id += 1;
        let seed = self.rng.next_u64();
        let map_params = StepParams::map_snapshot(&self.state);
        let reply = request(
            &mut self.conns[owner],
            &Message::StreamIngest { batch_id, seed, params: map_params, x: batch.to_vec() },
        )
        .with_context(|| format!("routing ingest batch {batch_id} to worker {owner}"))?;
        let deltas = expect_deltas(reply, owner)?;
        let delta = single_delta(&deltas, batch_id, owner)?;
        self.apply_window_delta(&delta.removed, &delta.added)?;
        self.fifo.push_back(BatchRec { id: batch_id, owner, n });
        self.worker_points[owner] += n;
        self.window_points += n;

        // 3. Leader-decided batch-granular eviction in global FIFO order:
        // the worker reports the batch's current grouped statistics, which
        // move from the window accumulators into the frozen base. The FIFO
        // record is popped only after the round-trip and the folds succeed
        // — popping first would let a transient failure desynchronize the
        // leader's eviction order from the workers' forever.
        let mut evicted = 0usize;
        while self.window_points > self.cfg.window.max(1) {
            let rec = *self.fifo.front().expect("window overflow with an empty FIFO");
            let reply = request(
                &mut self.conns[rec.owner],
                &Message::StreamEvict { batch_ids: vec![rec.id] },
            )
            .with_context(|| {
                format!("evicting batch {} from worker {}", rec.id, rec.owner)
            })?;
            let deltas = expect_deltas(reply, rec.owner)?;
            let delta = single_delta(&deltas, rec.id, rec.owner)?;
            check_bundle(&delta.added, self.k(), d, "evict")?;
            for (kk, d) in delta.added.iter().enumerate() {
                for h in 0..2 {
                    self.win[kk][h].try_unmerge(&d[h])?;
                    self.base[kk][h].try_merge(&d[h])?;
                }
            }
            self.fifo.pop_front();
            self.worker_points[rec.owner] -= rec.n;
            self.window_points -= rec.n;
            evicted += rec.n;
        }
        sync_model_stats(&mut self.state, &self.base, &self.win);

        // 4. Restricted sweeps: leader samples steps (a)–(d), workers run
        // (e)/(f) over their window slices, leader folds the per-batch
        // deltas in ascending global batch id order.
        let opts = SamplerOptions { sub_restart_every: 0, ..SamplerOptions::default() };
        for _ in 0..self.cfg.sweeps {
            if self.window_points == 0 {
                break;
            }
            sample_weights(&mut self.state, &mut self.rng);
            sample_sub_weights(&mut self.state, &mut self.rng);
            sample_params(&mut self.state, &opts, &mut self.rng);
            let msg = Message::StreamSweep(StepParams::snapshot(&self.state));
            // Write to all first (overlap worker compute), then collect.
            for conn in self.conns.iter_mut() {
                write_message(conn, &msg)?;
            }
            let mut all: Vec<BatchDelta> = Vec::new();
            for (i, conn) in self.conns.iter_mut().enumerate() {
                match wire::read_message(conn)? {
                    Message::StatsDelta(ds) => all.extend(ds),
                    Message::Error(e) => bail!("worker {i}: {e}"),
                    other => bail!("worker {i}: unexpected sweep reply {other:?}"),
                }
            }
            // Canonical fold order: ascending global batch id — the fold
            // sequence is identical no matter how batches are partitioned
            // across workers. Every delta must name a batch the leader
            // actually has windowed, exactly once: a ghost id (corrupt
            // frame, confused worker) folded blindly would corrupt the
            // accumulators with no error.
            let resident: std::collections::HashSet<u64> =
                self.fifo.iter().map(|r| r.id).collect();
            all.sort_by_key(|dlt| dlt.batch_id);
            for pair in all.windows(2) {
                if pair[0].batch_id == pair[1].batch_id {
                    bail!("duplicate sweep delta for batch {}", pair[0].batch_id);
                }
            }
            for dlt in &all {
                if !resident.contains(&dlt.batch_id) {
                    bail!("sweep delta for unknown batch {}", dlt.batch_id);
                }
                self.apply_window_delta(&dlt.removed, &dlt.added)?;
            }
            if !all.is_empty() {
                sync_model_stats(&mut self.state, &self.base, &self.win);
            }
        }

        self.ingested += n as u64;
        self.state.n_total += n;
        Ok(IngestSummary {
            accepted: n,
            window: self.window_points,
            evicted,
            k: self.k(),
        })
    }

    /// `win -= removed; win += added` for one batch delta, with wire-input
    /// validation (cluster count, family, dimensionality).
    fn apply_window_delta(
        &mut self,
        removed: &[[Stats; 2]],
        added: &[[Stats; 2]],
    ) -> Result<()> {
        let k = self.k();
        let d = self.dim();
        check_bundle(removed, k, d, "removed")?;
        check_bundle(added, k, d, "added")?;
        for (kk, d) in removed.iter().enumerate() {
            for h in 0..2 {
                self.win[kk][h].try_unmerge(&d[h])?;
            }
        }
        for (kk, d) in added.iter().enumerate() {
            for h in 0..2 {
                self.win[kk][h].try_merge(&d[h])?;
            }
        }
        Ok(())
    }
}

impl Drop for DistributedFitter {
    fn drop(&mut self) {
        self.shutdown().ok();
    }
}

impl StreamFitter for DistributedFitter {
    fn dim(&self) -> usize {
        DistributedFitter::dim(self)
    }
    fn k(&self) -> usize {
        DistributedFitter::k(self)
    }
    fn ingest(&mut self, batch: &[f64]) -> Result<IngestSummary> {
        DistributedFitter::ingest(self, batch)
    }
    fn snapshot(&self) -> Result<ModelSnapshot> {
        DistributedFitter::snapshot(self)
    }
    fn ingested(&self) -> u64 {
        DistributedFitter::ingested(self)
    }
}

/// Unwrap a `StatsDelta` reply.
fn expect_deltas(reply: Message, worker: usize) -> Result<Vec<BatchDelta>> {
    match reply {
        Message::StatsDelta(ds) => Ok(ds),
        other => bail!("worker {worker}: expected StatsDelta, got {other:?}"),
    }
}

/// Require exactly one delta, for the named batch.
fn single_delta(deltas: &[BatchDelta], batch_id: u64, worker: usize) -> Result<BatchDelta> {
    match deltas {
        [d] if d.batch_id == batch_id => Ok(d.clone()),
        [d] => bail!("worker {worker}: delta for batch {}, want {batch_id}", d.batch_id),
        _ => bail!("worker {worker}: {} deltas for batch {batch_id}, want 1", deltas.len()),
    }
}

/// A wire-decoded stats bundle must be empty or exactly K entries of the
/// model's dimensionality (`try_merge` checks families but zips over
/// dimensions, so a corrupt width must be rejected before folding).
fn check_bundle(bundle: &[[Stats; 2]], k: usize, d: usize, what: &str) -> Result<()> {
    if bundle.is_empty() {
        return Ok(());
    }
    if bundle.len() != k {
        bail!("worker returned {} `{what}` clusters, want {k}", bundle.len());
    }
    for (kk, pair) in bundle.iter().enumerate() {
        for s in pair {
            if s.dim() != d {
                bail!(
                    "worker `{what}` stats for cluster {kk} have dimension {}, want {d}",
                    s.dim()
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::distributed::worker::spawn_local;
    use crate::serve::ModelSnapshot;
    use crate::stats::{NiwPrior, Prior};

    /// A tiny two-blob snapshot (mirrors the local fitter's test seed).
    fn seed_snapshot() -> ModelSnapshot {
        let prior = Prior::Niw(NiwPrior::weak(2));
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let mut state = DpmmState::new(1.0, prior.clone(), 2, 200, &mut rng);
        for (k, center) in [(0usize, -6.0f64), (1, 6.0)] {
            let mut s = prior.empty_stats();
            for i in 0..100 {
                s.add(&[center + 0.03 * (i % 9) as f64, 0.05 * (i % 7) as f64 - 0.15]);
            }
            state.clusters[k].stats = s;
        }
        ModelSnapshot::from_state(&state).unwrap()
    }

    fn blob_batch(center: f64, n: usize, phase: usize) -> Vec<f64> {
        let mut v = Vec::with_capacity(n * 2);
        for i in 0..n {
            v.push(center + 0.04 * ((i + phase) % 11) as f64 - 0.2);
            v.push(0.03 * ((i * 3 + phase) % 5) as f64);
        }
        v
    }

    fn cluster_fitter(workers: usize, window: usize) -> DistributedFitter {
        let snap = seed_snapshot();
        let addrs: Vec<String> = (0..workers).map(|_| spawn_local().unwrap()).collect();
        DistributedFitter::from_snapshot(
            &snap,
            DistributedStreamConfig {
                workers: addrs,
                worker_threads: 2,
                window,
                sweeps: 2,
                alpha: 2.0,
                seed: 9,
                ..DistributedStreamConfig::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn distributed_ingest_tracks_blob_masses() {
        let mut f = cluster_fitter(2, 4096);
        let before = f.counts();
        f.ingest(&blob_batch(-6.0, 30, 0)).unwrap();
        let s = f.ingest(&blob_batch(6.0, 30, 1)).unwrap();
        assert_eq!(s.accepted, 30);
        assert_eq!(s.window, 60);
        assert_eq!(s.evicted, 0);
        assert_eq!(s.k, 2);
        let after = f.counts();
        assert!((after[0] - before[0] - 30.0).abs() < 1e-6, "{before:?} -> {after:?}");
        assert!((after[1] - before[1] - 30.0).abs() < 1e-6);
        assert_eq!(f.ingested(), 60);
        assert!(f.snapshot().is_ok());
        f.shutdown().unwrap();
    }

    #[test]
    fn eviction_preserves_total_mass() {
        // window = 64 < 4 × 30 ingested: whole batches retire in FIFO
        // order, and the evidence stays in the model.
        let mut f = cluster_fitter(2, 64);
        let mut evicted = 0;
        for phase in 0..4 {
            evicted += f.ingest(&blob_batch(-6.0, 30, phase)).unwrap().evicted;
        }
        assert!(evicted > 0, "window 64 must have overflowed");
        assert!(f.window_len() <= 64);
        assert_eq!(f.window_len() + evicted, 120);
        let total: f64 = f.counts().iter().sum();
        assert!((total - 200.0 - 120.0).abs() < 1e-6, "total mass {total}");
    }

    #[test]
    fn rejects_bad_batches_and_configs() {
        let mut f = cluster_fitter(1, 128);
        assert!(f.ingest(&[1.0, 2.0, 3.0]).is_err()); // not a multiple of d
        assert!(f.ingest(&[f64::NAN, 0.0]).is_err());
        let s = f.ingest(&[]).unwrap();
        assert_eq!(s.accepted, 0);
        let snap = seed_snapshot();
        assert!(DistributedFitter::from_snapshot(
            &snap,
            DistributedStreamConfig::default() // no workers
        )
        .is_err());
        assert!(DistributedFitter::from_snapshot(
            &snap,
            DistributedStreamConfig {
                workers: vec![spawn_local().unwrap()],
                decay: 0.0,
                ..DistributedStreamConfig::default()
            }
        )
        .is_err());
    }

    #[test]
    fn least_loaded_routing_balances_workers() {
        let mut f = cluster_fitter(2, 1 << 20);
        for phase in 0..6 {
            f.ingest(&blob_batch(-6.0, 20, phase)).unwrap();
        }
        // Equal batch sizes ⇒ strict alternation ⇒ a 60/60 split.
        assert_eq!(f.worker_points, vec![60, 60]);
    }
}
