//! # dpmm-subclusters
//!
//! A Rust + JAX/Pallas (AOT via PJRT) reproduction of
//! *"CPU- and GPU-based Distributed Sampling in Dirichlet Process Mixtures
//! for Large-scale Analysis"* (Dinari, Zamir, Fisher III, Freifeld; 2022).
//!
//! The crate implements the Chang & Fisher III (NIPS 2013) sub-cluster
//! split/merge DPMM sampler with three interchangeable execution backends:
//!
//! * [`backend::native`] — multi-core CPU shard pool (the paper's Julia
//!   package analog),
//! * [`backend::xla`] — AOT-compiled JAX/Pallas shard-step artifacts executed
//!   through the PJRT C API (the paper's CUDA/C++ package analog),
//! * [`backend::distributed`] — TCP leader/worker processes that exchange
//!   only parameters and sufficient statistics (the paper's multi-machine
//!   Julia mode analog).
//!
//! After a fit, the [`serve`] subsystem freezes the chain into an immutable
//! [`serve::ModelSnapshot`] and serves batched posterior-predictive queries
//! (MAP assignment, membership probabilities, anomaly scores) in-process or
//! over TCP with micro-batching.
//!
//! Quickstart:
//!
//! ```no_run
//! use dpmm::prelude::*;
//!
//! let mut rng = Xoshiro256pp::seed_from_u64(42);
//! let data = GmmSpec::default_with(10_000, 2, 6).generate(&mut rng);
//! let fit = DpmmFit::new(DpmmParams::gaussian_default(2))
//!     .iterations(100)
//!     .seed(7)
//!     .fit(&data.points)
//!     .unwrap();
//! println!("discovered K = {}", fit.num_clusters());
//! ```

pub mod backend;
pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datagen;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod stats;
pub mod stream;
pub mod telemetry;
pub mod util;

/// Convenience re-exports for the common fitting workflow.
pub mod prelude {
    pub use crate::config::{DpmmParams, PriorSpec};
    pub use crate::coordinator::{DpmmFit, FitResult};
    pub use crate::datagen::{Dataset, GmmSpec, MultinomialSpec};
    pub use crate::linalg::Matrix;
    pub use crate::metrics::nmi;
    pub use crate::rng::{Rng, Xoshiro256pp};
    pub use crate::serve::{DpmmClient, ModelSnapshot, ScoringEngine};
}
