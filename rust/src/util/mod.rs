//! Small self-contained substrates the paper's packages took from external
//! libraries (jsoncpp, cnpy, …), rebuilt here with no dependencies.

pub mod json;
pub mod npy;
pub mod threadpool;
pub mod timer;
