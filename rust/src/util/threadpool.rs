//! A small scoped fork-join helper (the paper's `omp parallel for` /
//! Julia `@distributed` substrate for the single-machine multi-core path).
//!
//! [`parallel_map`] splits `items` into contiguous chunks, runs `f` on worker
//! threads via `std::thread::scope`, and returns results in input order.
//! Threads are spawned per call; for the shard sizes this crate works with
//! (≥ thousands of points per task) spawn cost is noise, and scoped threads
//! let closures borrow the data shards without `Arc` plumbing.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default (respects `DPMM_THREADS`).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DPMM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Map `f` over `items` using up to `threads` workers; results in input order.
///
/// Work-stealing is index-based: workers atomically claim the next item, so
/// uneven task costs (e.g. shards with different live-cluster mixes) balance.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots.into_iter().map(|s| s.into_inner().unwrap().expect("worker panicked")).collect()
}

/// Parallel for over `0..n` with no results collected.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |i, &x| i + x), vec![1, 3, 5]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u8> = vec![];
        assert!(parallel_map(&items, 4, |_, &x| x).is_empty());
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        use std::sync::atomic::AtomicU64;
        let sum = AtomicU64::new(0);
        parallel_for(100, 7, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn uneven_workloads_balance() {
        // Tasks with wildly different costs still all complete and in order.
        let items: Vec<usize> = (0..64).collect();
        let out = parallel_map(&items, 4, |_, &x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) as u64 {
                acc = acc.wrapping_add(i);
            }
            (x, acc)
        });
        for (i, (x, _)) in out.iter().enumerate() {
            assert_eq!(i, *x);
        }
    }
}
