//! Wall-clock timing helpers used by the coordinator metrics and the bench
//! harnesses (the crate has no `criterion`; benches are `harness = false`
//! binaries built on these).
//!
//! Since the telemetry subsystem landed there is **one timing substrate**:
//! [`PhaseTimer`] keeps its local per-fit accumulation (the `FitResult`
//! summary needs it regardless of telemetry), but every recorded phase is
//! also observed into the process-global
//! `dpmm_sweep_phase_seconds{phase=...}` histogram when telemetry is
//! enabled, so the same numbers are scrapeable live. Hot *inner* loops
//! must not use this type per item — they coarse-tick via
//! [`crate::telemetry::Stopwatch`] at chunk granularity instead (a clock
//! read costs as much as a small tile column; see docs/OBSERVABILITY.md).

use std::time::{Duration, Instant};

/// A simple stopwatch accumulating named phase durations.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name` (accumulates on repeats).
    pub fn time<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(name, t0.elapsed());
        r
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        if crate::telemetry::enabled() {
            crate::telemetry::catalog::sweep_phase(name).observe_duration(d);
        }
        if let Some((_, acc)) = self.phases.iter_mut().find(|(n, _)| n == name) {
            *acc += d;
        } else {
            self.phases.push((name.to_string(), d));
        }
    }

    pub fn get(&self, name: &str) -> Duration {
        self.phases
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .unwrap_or(Duration::ZERO)
    }

    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }

    pub fn phases(&self) -> &[(String, Duration)] {
        &self.phases
    }

    /// One-line summary like `assign=1.23s stats=0.45s`.
    pub fn summary(&self) -> String {
        self.phases
            .iter()
            .map(|(n, d)| format!("{}={:.3}s", n, d.as_secs_f64()))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Run `f` `iters` times, return (mean, min, max) seconds per call.
pub fn bench_loop<F: FnMut()>(iters: usize, mut f: F) -> (f64, f64, f64) {
    assert!(iters > 0);
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    (mean, min, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_by_name() {
        let mut t = PhaseTimer::new();
        t.add("a", Duration::from_millis(10));
        t.add("a", Duration::from_millis(5));
        t.add("b", Duration::from_millis(1));
        assert_eq!(t.get("a"), Duration::from_millis(15));
        assert_eq!(t.total(), Duration::from_millis(16));
        assert!(t.summary().starts_with("a=0.015s"));
    }

    #[test]
    fn time_records_and_returns() {
        let mut t = PhaseTimer::new();
        let v = t.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert!(t.get("work") > Duration::ZERO);
    }

    #[test]
    fn bench_loop_stats_ordered() {
        let (mean, min, max) = bench_loop(5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(min <= mean && mean <= max);
    }
}
