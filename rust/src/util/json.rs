//! Minimal JSON parser / writer (the paper's `jsoncpp` substrate).
//!
//! Supports the full JSON grammar (RFC 8259): objects, arrays, strings with
//! escapes (incl. `\uXXXX` and surrogate pairs), numbers, booleans, null.
//! Used for `--params_path` model configs and result files, mirroring the
//! paper's CLI surface.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns `None` for non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `[f64]` array helper (for vector-valued hyperparameters).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect::<Vec<_>>())
            .filter(|v| Some(v.len()) == self.as_arr().map(<[Json]>::len))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { msg: msg.into(), offset: self.pos })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte 0x{c:02x}")),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("expected low surrogate");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        match char::from_u32(cp) {
                            Some(c) => out.push(c),
                            None => return self.err("invalid code point"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences byte-faithfully.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return self.err("truncated utf-8");
                        }
                        match std::str::from_utf8(&self.bytes[start..start + len]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = start + len;
                            }
                            Err(_) => return self.err("invalid utf-8"),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("truncated \\u escape"),
            };
            let d = (c as char).to_digit(16);
            match d {
                Some(d) => v = v * 16 + d,
                None => return self.err("bad hex digit"),
            }
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Json, out: &mut String, indent: usize, pretty: bool) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                    if pretty {
                        out.push(' ');
                    }
                }
                write_value(item, out, indent, pretty);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                }
                write_escaped(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(val, out, indent + 1, pretty);
            }
            if pretty && !map.is_empty() {
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
            }
            out.push('}');
        }
    }
}

/// Serialize compactly.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out, 0, false);
    out
}

/// Serialize with 2-space indentation (result files).
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out, 0, true);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"alpha": 10.0, "prior": {"nu": 5, "psi": [1, 0, 0, 1]}, "names": ["a","b"]}"#)
            .unwrap();
        assert_eq!(v.get("alpha").unwrap().as_f64(), Some(10.0));
        assert_eq!(v.get("prior").unwrap().get("nu").unwrap().as_usize(), Some(5));
        assert_eq!(
            v.get("prior").unwrap().get("psi").unwrap().as_f64_vec().unwrap(),
            vec![1.0, 0.0, 0.0, 1.0]
        );
        assert_eq!(v.get("names").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"c\"A😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\"A😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo עברית\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo עברית");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\q\"").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,{"b":null},true],"c":"x\"y"}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
        let outp = to_string_pretty(&v);
        assert_eq!(parse(&outp).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(to_string(&parse("{}").unwrap()), "{}");
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(to_string(&Json::Num(3.0)), "3");
        assert_eq!(to_string(&Json::Num(3.25)), "3.25");
    }
}
