//! `.npy` v1.0 reader/writer (the paper's `cnpy` / `NPZ.jl` substrate).
//!
//! Supports C-contiguous arrays of `f32`, `f64`, `i32`, `i64` in little
//! endian, which covers the paper's `model_path` / `result_path` interchange
//! (data matrices and label vectors).

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Element type tag for a parsed array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F64,
    I32,
    I64,
}

impl Dtype {
    fn descr(self) -> &'static str {
        match self {
            Dtype::F32 => "<f4",
            Dtype::F64 => "<f8",
            Dtype::I32 => "<i4",
            Dtype::I64 => "<i8",
        }
    }
    fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F64 | Dtype::I64 => 8,
        }
    }
    fn from_descr(d: &str) -> Result<Dtype> {
        // numpy writes '<f8'; '|' for byte-order-free and '=' native also occur.
        let d = d.trim_start_matches(['<', '=', '|']);
        Ok(match d {
            "f4" => Dtype::F32,
            "f8" => Dtype::F64,
            "i4" => Dtype::I32,
            "i8" => Dtype::I64,
            other => bail!("unsupported npy dtype descr '{other}'"),
        })
    }
}

/// An n-dimensional array read from / written to `.npy`.
#[derive(Debug, Clone, PartialEq)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: NpyData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum NpyData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl NpyArray {
    pub fn dtype(&self) -> Dtype {
        match &self.data {
            NpyData::F32(_) => Dtype::F32,
            NpyData::F64(_) => Dtype::F64,
            NpyData::I32(_) => Dtype::I32,
            NpyData::I64(_) => Dtype::I64,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as f64 regardless of storage type (copies).
    pub fn to_f64(&self) -> Vec<f64> {
        match &self.data {
            NpyData::F32(v) => v.iter().map(|&x| x as f64).collect(),
            NpyData::F64(v) => v.clone(),
            NpyData::I32(v) => v.iter().map(|&x| x as f64).collect(),
            NpyData::I64(v) => v.iter().map(|&x| x as f64).collect(),
        }
    }

    /// View as usize labels (fails on negatives / non-integers).
    pub fn to_labels(&self) -> Result<Vec<usize>> {
        let out: Option<Vec<usize>> = match &self.data {
            NpyData::I32(v) => v.iter().map(|&x| usize::try_from(x).ok()).collect(),
            NpyData::I64(v) => v.iter().map(|&x| usize::try_from(x).ok()).collect(),
            NpyData::F32(v) => v
                .iter()
                .map(|&x| if x >= 0.0 && x.fract() == 0.0 { Some(x as usize) } else { None })
                .collect(),
            NpyData::F64(v) => v
                .iter()
                .map(|&x| if x >= 0.0 && x.fract() == 0.0 { Some(x as usize) } else { None })
                .collect(),
        };
        out.context("npy array is not a non-negative integer label vector")
    }
}

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Parse the python-dict literal numpy writes in the header, e.g.
/// `{'descr': '<f8', 'fortran_order': False, 'shape': (3, 4), }`
fn parse_header(h: &str) -> Result<(Dtype, bool, Vec<usize>)> {
    fn field<'a>(h: &'a str, key: &str) -> Result<&'a str> {
        let pat = format!("'{key}':");
        let i = h.find(&pat).with_context(|| format!("npy header missing '{key}'"))?;
        Ok(h[i + pat.len()..].trim_start())
    }
    let descr_rest = field(h, "descr")?;
    if !descr_rest.starts_with('\'') {
        bail!("structured npy dtypes unsupported");
    }
    let end = descr_rest[1..].find('\'').context("unterminated descr")? + 1;
    let dtype = Dtype::from_descr(&descr_rest[1..end])?;

    let fortran_rest = field(h, "fortran_order")?;
    let fortran = fortran_rest.starts_with("True");

    let shape_rest = field(h, "shape")?;
    if !shape_rest.starts_with('(') {
        bail!("bad shape in npy header");
    }
    let close = shape_rest.find(')').context("unterminated shape")?;
    let inner = &shape_rest[1..close];
    let mut shape = Vec::new();
    for tok in inner.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        shape.push(tok.parse::<usize>().with_context(|| format!("bad dim '{tok}'"))?);
    }
    Ok((dtype, fortran, shape))
}

/// Read an `.npy` file.
pub fn read(path: impl AsRef<Path>) -> Result<NpyArray> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    read_bytes(&bytes)
}

/// Read an `.npy` image from memory.
pub fn read_bytes(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != MAGIC {
        bail!("not an npy file (bad magic)");
    }
    let major = bytes[6];
    let (header_len, header_start) = match major {
        1 => (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10),
        2 | 3 => {
            if bytes.len() < 12 {
                bail!("truncated npy v2 header");
            }
            (u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize, 12)
        }
        v => bail!("unsupported npy version {v}"),
    };
    let header_end = header_start + header_len;
    if bytes.len() < header_end {
        bail!("truncated npy header");
    }
    let header = std::str::from_utf8(&bytes[header_start..header_end])
        .context("npy header is not utf-8")?;
    let (dtype, fortran, shape) = parse_header(header)?;
    if fortran && shape.len() > 1 {
        bail!("fortran_order npy arrays unsupported");
    }
    let count: usize = shape.iter().product();
    let body = &bytes[header_end..];
    if body.len() < count * dtype.size() {
        bail!("npy body too short: want {} elements", count);
    }
    let data = match dtype {
        Dtype::F32 => NpyData::F32(
            body.chunks_exact(4).take(count).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        Dtype::F64 => NpyData::F64(
            body.chunks_exact(8).take(count).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        Dtype::I32 => NpyData::I32(
            body.chunks_exact(4).take(count).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        Dtype::I64 => NpyData::I64(
            body.chunks_exact(8).take(count).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
    };
    Ok(NpyArray { shape, data })
}

fn header_string(dtype: Dtype, shape: &[usize]) -> Vec<u8> {
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!("({})", shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")),
    };
    let mut h = format!(
        "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
        dtype.descr(),
        shape_str
    );
    // Pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64, ending in \n.
    let unpadded = 10 + h.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    h.push_str(&" ".repeat(pad));
    h.push('\n');
    h.into_bytes()
}

/// Write an `.npy` file (v1.0, little endian, C order).
pub fn write(path: impl AsRef<Path>, arr: &NpyArray) -> Result<()> {
    let count: usize = arr.shape.iter().product();
    let n = match &arr.data {
        NpyData::F32(v) => v.len(),
        NpyData::F64(v) => v.len(),
        NpyData::I32(v) => v.len(),
        NpyData::I64(v) => v.len(),
    };
    if n != count {
        bail!("shape {:?} does not match data length {}", arr.shape, n);
    }
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let header = header_string(arr.dtype(), &arr.shape);
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(&header)?;
    match &arr.data {
        NpyData::F32(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        NpyData::F64(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        NpyData::I32(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        NpyData::I64(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

/// Convenience: write a 2-D f64 row-major matrix.
pub fn write_matrix_f64(path: impl AsRef<Path>, rows: usize, cols: usize, data: &[f64]) -> Result<()> {
    write(path, &NpyArray { shape: vec![rows, cols], data: NpyData::F64(data.to_vec()) })
}

/// Convenience: read any 2-D numeric array as (rows, cols, row-major f64).
pub fn read_matrix_f64(path: impl AsRef<Path>) -> Result<(usize, usize, Vec<f64>)> {
    let arr = read(path)?;
    if arr.shape.len() != 2 {
        bail!("expected 2-D array, got shape {:?}", arr.shape);
    }
    Ok((arr.shape[0], arr.shape[1], arr.to_f64()))
}

/// Read raw bytes from a reader until EOF (helper for streamed npy bodies).
pub fn read_all(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("dpmm_npy_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip_f64_2d() {
        let arr = NpyArray {
            shape: vec![2, 3],
            data: NpyData::F64(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
        };
        let p = tmp("f64_2d.npy");
        write(&p, &arr).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back, arr);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_all_dtypes_1d() {
        for data in [
            NpyData::F32(vec![1.5, -2.5]),
            NpyData::F64(vec![1e-300, 2.0]),
            NpyData::I32(vec![-7, 9]),
            NpyData::I64(vec![1 << 40, -3]),
        ] {
            let arr = NpyArray { shape: vec![2], data };
            let p = tmp("dtypes.npy");
            write(&p, &arr).unwrap();
            assert_eq!(read(&p).unwrap(), arr);
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn header_is_64_aligned() {
        let h = header_string(Dtype::F64, &[100, 32]);
        assert_eq!((10 + h.len()) % 64, 0);
        assert_eq!(*h.last().unwrap(), b'\n');
    }

    #[test]
    fn labels_conversion() {
        let arr = NpyArray { shape: vec![3], data: NpyData::I64(vec![0, 2, 1]) };
        assert_eq!(arr.to_labels().unwrap(), vec![0, 2, 1]);
        let bad = NpyArray { shape: vec![1], data: NpyData::I64(vec![-1]) };
        assert!(bad.to_labels().is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_bytes(b"NOTNUMPYxxxx").is_err());
    }

    #[test]
    fn shape_mismatch_rejected_on_write() {
        let arr = NpyArray { shape: vec![4], data: NpyData::F32(vec![0.0; 3]) };
        assert!(write(tmp("bad.npy"), &arr).is_err());
    }

    #[test]
    fn parses_numpy_style_header() {
        let (d, f, s) =
            parse_header("{'descr': '<f8', 'fortran_order': False, 'shape': (3, 4), }").unwrap();
        assert_eq!(d, Dtype::F64);
        assert!(!f);
        assert_eq!(s, vec![3, 4]);
        let (_, _, s1) =
            parse_header("{'descr': '<i4', 'fortran_order': False, 'shape': (7,), }").unwrap();
        assert_eq!(s1, vec![7]);
    }
}
