//! Row-major dense `f64` matrix.

use std::ops::{Index, IndexMut};

/// A dense row-major matrix of `f64`.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Self { rows, cols, data }
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(v: &[f64]) -> Self {
        let mut m = Self::zeros(v.len(), v.len());
        for (i, &x) in v.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn data(&self) -> &[f64] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// General matmul self (r×k) · other (k×c).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        // ikj loop order: stream through `other` rows, accumulate into out row.
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &aik) in a_row.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    /// `L · A` where `self` is lower-triangular — exploits sparsity.
    pub fn matmul_lower(&self, a: &Matrix) -> Matrix {
        assert_eq!(self.rows, self.cols);
        assert_eq!(self.cols, a.rows);
        let mut out = Matrix::zeros(self.rows, a.cols);
        for i in 0..self.rows {
            let out_row_range = i * a.cols..(i + 1) * a.cols;
            for k in 0..=i {
                let lik = self[(i, k)];
                if lik == 0.0 {
                    continue;
                }
                let b_row = a.row(k);
                let out_row = &mut out.data[out_row_range.clone()];
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += lik * b;
                }
            }
        }
        out
    }

    /// `A · Aᵀ` (always symmetric PSD).
    pub fn mul_transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.rows);
        for i in 0..self.rows {
            for j in 0..=i {
                let mut acc = 0.0;
                let (ri, rj) = (self.row(i), self.row(j));
                for (a, b) in ri.iter().zip(rj) {
                    acc += a * b;
                }
                out[(i, j)] = acc;
                out[(j, i)] = acc;
            }
        }
        out
    }

    /// In-place `self += other`.
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// In-place `self -= other`.
    pub fn sub_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    /// In-place scalar multiply.
    pub fn scale(&mut self, s: f64) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Out-of-place scalar multiply.
    pub fn scaled(&self, s: f64) -> Matrix {
        let mut m = self.clone();
        m.scale(s);
        m
    }

    /// Rank-1 update `self += s · v vᵀ`.
    pub fn add_outer(&mut self, v: &[f64], s: f64) {
        assert_eq!(self.rows, v.len());
        assert_eq!(self.cols, v.len());
        for i in 0..self.rows {
            let vi = v[i] * s;
            let row = self.row_mut(i);
            for (r, &vj) in row.iter_mut().zip(v) {
                *r += vi * vj;
            }
        }
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Cholesky factorization: returns lower-triangular `L` with `L Lᵀ = self`,
    /// or `None` if the matrix is not (numerically) positive definite.
    pub fn cholesky(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols, "cholesky needs square matrix");
        let n = self.rows;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 || !sum.is_finite() {
                        return None;
                    }
                    l[(i, i)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Some(l)
    }

    /// Inverse of a lower-triangular matrix.
    pub fn lower_inverse(&self) -> Matrix {
        let n = self.rows;
        let mut inv = Matrix::zeros(n, n);
        for i in 0..n {
            inv[(i, i)] = 1.0 / self[(i, i)];
            for j in 0..i {
                let mut acc = 0.0;
                for k in j..i {
                    acc += self[(i, k)] * inv[(k, j)];
                }
                inv[(i, j)] = -acc / self[(i, i)];
            }
        }
        inv
    }

    /// SPD inverse via Cholesky. Returns `None` when not SPD.
    pub fn spd_inverse(&self) -> Option<Matrix> {
        let l = self.cholesky()?;
        let linv = l.lower_inverse();
        // A⁻¹ = L⁻ᵀ L⁻¹
        Some(linv.transpose().matmul(&linv))
    }

    /// Frobenius norm of `self − other` (test helper).
    pub fn frob_dist(&self, other: &Matrix) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Force exact symmetry: self ← (self + selfᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..i {
                let m = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = m;
                self[(j, i)] = m;
            }
        }
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).sum()
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn lower_inverse_correct() {
        let l = Matrix::from_rows(&[&[2.0, 0.0, 0.0], &[1.0, 3.0, 0.0], &[-1.0, 0.5, 1.5]]);
        let inv = l.lower_inverse();
        let prod = l.matmul(&inv);
        assert!(prod.frob_dist(&Matrix::identity(3)) < 1e-12);
    }

    #[test]
    fn spd_inverse_roundtrip() {
        let b = Matrix::from_rows(&[&[2.0, 1.0], &[0.5, 3.0]]);
        let mut a = b.mul_transpose();
        a[(0, 0)] += 1.0;
        a[(1, 1)] += 1.0;
        let inv = a.spd_inverse().unwrap();
        assert!(a.matmul(&inv).frob_dist(&Matrix::identity(2)) < 1e-12);
    }

    #[test]
    fn add_outer_matches_manual() {
        let mut m = Matrix::zeros(2, 2);
        m.add_outer(&[2.0, -1.0], 3.0);
        assert_eq!(m, Matrix::from_rows(&[&[12.0, -6.0], &[-6.0, 3.0]]));
    }

    #[test]
    fn matmul_lower_matches_general() {
        let l = Matrix::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(l.matmul_lower(&a), l.matmul(&a));
    }

    #[test]
    fn matvec_and_trace() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(a.trace(), 5.0);
    }

    #[test]
    fn diag_and_symmetrize() {
        let mut m = Matrix::diag(&[1.0, 2.0]);
        m[(0, 1)] = 1.0;
        m.symmetrize();
        assert_eq!(m[(1, 0)], 0.5);
        assert_eq!(m[(0, 1)], 0.5);
    }
}
