//! Tile-granular kernels for the batched assignment hot path.
//!
//! The paper's headline speedups come from recasting the per-point Gaussian
//! log-likelihood `c − ½‖W(x−μ)‖²` as a batched matmul over many points at
//! once. These kernels operate on a *feature-major tile*: a `d × m` scratch
//! buffer holding `m` points as columns (row `i` = feature `i` across the
//! tile, unit stride over points), so every inner loop is a contiguous
//! axpy/dot of length `m` that the compiler auto-vectorizes.
//!
//! FP-determinism contract: for each output element the floating-point
//! accumulation order is *identical* to the scalar oracle in
//! [`crate::sampler::KernelDesc::loglik`] (ascending `j`, then ascending
//! `i`), so the tiled and scalar assignment paths produce bitwise-identical
//! scores — and therefore bitwise-identical label sequences under a fixed
//! seed. See EXPERIMENTS.md §Perf.

use super::Matrix;

/// Transpose `m` row-major points of dimension `d` into the feature-major
/// tile layout: `out[i * m + t] = rows[t * d + i]`.
pub fn transpose_tile(rows: &[f64], d: usize, m: usize, out: &mut [f64]) {
    debug_assert!(rows.len() >= m * d);
    debug_assert!(out.len() >= d * m);
    for t in 0..m {
        let point = &rows[t * d..(t + 1) * d];
        for (i, &v) in point.iter().enumerate() {
            out[i * m + t] = v;
        }
    }
}

/// Blocked lower-triangular GEMM `Y = L · X` with `L` lower-triangular
/// `d × d` and `X` of shape `d × m` (both row-major). Columns are processed
/// in panels so the active strip of `X` and `Y` stays cache-resident while
/// the triangle of `L` streams through once per panel.
///
/// This is the unfused building block (kept `Matrix → Matrix` for reuse and
/// testability); the assignment hot path uses [`lower_affine_sqnorm`], which
/// fuses the affine offset and squared-norm reduction into the same pass.
pub fn gemm_lower_blocked(l: &Matrix, x: &Matrix) -> Matrix {
    assert_eq!(l.rows(), l.cols(), "L must be square");
    assert_eq!(l.cols(), x.rows(), "shape mismatch");
    const PANEL: usize = 128;
    let d = l.rows();
    let m = x.cols();
    let mut y = Matrix::zeros(d, m);
    let ld = l.data();
    let mut col = 0;
    while col < m {
        let w = PANEL.min(m - col);
        for i in 0..d {
            let row_range = i * m + col..i * m + col + w;
            for j in 0..=i {
                let lij = ld[i * d + j];
                let xrow = &x.data()[j * m + col..j * m + col + w];
                let yrow = &mut y.data_mut()[row_range.clone()];
                for (yv, &xv) in yrow.iter_mut().zip(xrow) {
                    *yv += lij * xv;
                }
            }
        }
        col += w;
    }
    y
}

/// Fused whitened-GEMM + squared-norm kernel:
/// `maha[t] = ‖W·x_t − b‖²` for the first `m` columns of the feature-major
/// tile `x` (row stride `m`), with `W` lower-triangular `d × d` (row-major
/// flat slice) and `b` a precomputed affine offset (`b = W·μ`, so no
/// per-point diff vector is ever formed).
///
/// `y` is caller scratch of length ≥ `m` (the current output row).
/// Accumulation order per element: `y = ((−b_i + W_i0·x_0) + W_i1·x_1) + …`,
/// then `maha += y²` for ascending `i` — exactly the scalar-oracle order.
pub fn lower_affine_sqnorm(
    w: &[f64],
    d: usize,
    b: &[f64],
    x: &[f64],
    m: usize,
    y: &mut [f64],
    maha: &mut [f64],
) {
    debug_assert!(w.len() >= d * d);
    debug_assert!(b.len() >= d);
    debug_assert!(x.len() >= d * m);
    debug_assert!(y.len() >= m && maha.len() >= m);
    maha[..m].fill(0.0);
    let mut off = 0;
    for i in 0..d {
        let bi = b[i];
        y[..m].fill(-bi);
        for (j, &wij) in w[off..off + i + 1].iter().enumerate() {
            let xrow = &x[j * m..j * m + m];
            for (yv, &xv) in y[..m].iter_mut().zip(xrow) {
                *yv += wij * xv;
            }
        }
        for (mh, &yv) in maha[..m].iter_mut().zip(y[..m].iter()) {
            *mh += yv * yv;
        }
        off += d;
    }
}

/// Batched dot product `acc[t] = Σ_j coef[j] · x[j][t]` over the first `m`
/// columns of the feature-major tile `x` (row stride `m`) — the multinomial
/// log-likelihood kernel (`coef = log θ`). Ascending-`j` accumulation,
/// matching the scalar oracle bitwise.
pub fn dot_accumulate_tile(coef: &[f64], x: &[f64], m: usize, acc: &mut [f64]) {
    debug_assert!(x.len() >= coef.len() * m);
    debug_assert!(acc.len() >= m);
    acc[..m].fill(0.0);
    for (j, &c) in coef.iter().enumerate() {
        let xrow = &x[j * m..j * m + m];
        for (a, &xv) in acc[..m].iter_mut().zip(xrow) {
            *a += c * xv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lower(d: usize, seed: u64) -> Matrix {
        let mut m = Matrix::zeros(d, d);
        let mut s = seed;
        for i in 0..d {
            for j in 0..=i {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                m[(i, j)] = ((s >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
            }
            m[(i, i)] += 1.5;
        }
        m
    }

    fn dense(r: usize, c: usize, seed: u64) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        let mut s = seed;
        for v in m.data_mut() {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
            *v = ((s >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
        }
        m
    }

    #[test]
    fn transpose_tile_roundtrip() {
        let d = 3;
        let m = 4;
        let rows: Vec<f64> = (0..d * m).map(|v| v as f64).collect();
        let mut out = vec![0.0; d * m];
        transpose_tile(&rows, d, m, &mut out);
        for t in 0..m {
            for i in 0..d {
                assert_eq!(out[i * m + t], rows[t * d + i]);
            }
        }
    }

    #[test]
    fn gemm_lower_blocked_matches_matmul() {
        for (d, m) in [(1, 1), (4, 7), (8, 200), (16, 131)] {
            let l = lower(d, d as u64);
            let x = dense(d, m, m as u64);
            let got = gemm_lower_blocked(&l, &x);
            let want = l.matmul(&x);
            assert!(got.frob_dist(&want) < 1e-12, "d={d} m={m}");
        }
    }

    #[test]
    fn lower_affine_sqnorm_matches_reference() {
        let d = 5;
        let m = 9;
        let l = lower(d, 3);
        let mu: Vec<f64> = (0..d).map(|i| 0.3 * i as f64 - 0.7).collect();
        // b = W·μ
        let b: Vec<f64> = (0..d)
            .map(|i| (0..=i).map(|j| l[(i, j)] * mu[j]).sum())
            .collect();
        let pts = dense(m, d, 17);
        let mut xt = vec![0.0; d * m];
        transpose_tile(pts.data(), d, m, &mut xt);
        let mut y = vec![0.0; m];
        let mut maha = vec![0.0; m];
        lower_affine_sqnorm(l.data(), d, &b, &xt, m, &mut y, &mut maha);
        for t in 0..m {
            // Reference: ‖L(x − μ)‖² via explicit diff.
            let x = pts.row(t);
            let mut want = 0.0;
            for i in 0..d {
                let mut acc = 0.0;
                for j in 0..=i {
                    acc += l[(i, j)] * (x[j] - mu[j]);
                }
                want += acc * acc;
            }
            assert!((maha[t] - want).abs() < 1e-9, "t={t}: {} vs {want}", maha[t]);
        }
    }

    #[test]
    fn dot_accumulate_tile_matches_scalar() {
        let d = 6;
        let m = 5;
        let coef: Vec<f64> = (0..d).map(|j| (j as f64 + 1.0).ln()).collect();
        let pts = dense(m, d, 5);
        let mut xt = vec![0.0; d * m];
        transpose_tile(pts.data(), d, m, &mut xt);
        let mut acc = vec![0.0; m];
        dot_accumulate_tile(&coef, &xt, m, &mut acc);
        for t in 0..m {
            let want: f64 = pts.row(t).iter().zip(&coef).map(|(&x, &c)| x * c).sum();
            assert!((acc[t] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn remainder_tiles_use_only_m_columns() {
        // Buffers larger than m: only the first m entries are touched.
        let d = 2;
        let l = lower(d, 9);
        let b = vec![0.0; d];
        let xt = vec![1.0; d * 3];
        let mut y = vec![f64::NAN; 8];
        let mut maha = vec![f64::NAN; 8];
        lower_affine_sqnorm(l.data(), d, &b, &xt, 3, &mut y, &mut maha);
        assert!(maha[..3].iter().all(|v| v.is_finite()));
        assert!(maha[3..].iter().all(|v| v.is_nan()));
    }
}
