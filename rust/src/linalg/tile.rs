//! Tile-granular kernels for the batched assignment hot path.
//!
//! The paper's headline speedups come from recasting the per-point Gaussian
//! log-likelihood `c − ½‖W(x−μ)‖²` as a batched matmul over many points at
//! once. These kernels operate on a *feature-major tile*: a `d × m` scratch
//! buffer holding `m` points as columns (row `i` = feature `i` across the
//! tile, unit stride over points), so every inner loop is a contiguous
//! axpy/dot of length `m` that the compiler auto-vectorizes.
//!
//! FP-determinism contract: for each output element the floating-point
//! accumulation order is *identical* to the scalar oracle in
//! [`crate::sampler::KernelDesc::loglik`] (ascending `j`, then ascending
//! `i`), so the tiled and scalar assignment paths produce bitwise-identical
//! scores — and therefore bitwise-identical label sequences under a fixed
//! seed. See EXPERIMENTS.md §Perf.
//!
//! # Explicit SIMD (runtime-dispatched)
//!
//! Each kernel has an AVX2 body selected at runtime behind [`simd_active`]
//! (cached feature detection + the `DPMM_SIMD` knob). The vector lanes run
//! *across the tile dimension `t`* — the per-element accumulation order
//! (ascending `j`, then ascending `i`) is untouched, and the AVX2 bodies
//! use separate multiply and add instructions (never FMA, whose single
//! rounding differs), so every lane computes bit-for-bit the scalar
//! expression `acc = acc + c·x`. SIMD on/off therefore preserves the
//! bitwise label contract above; `tests/prop_kernel_equiv.rs` pins it.
//! The AVX2 bodies additionally keep the output row in registers across
//! the whole `j` loop (one store per row instead of one load+store per
//! `(j, t)`), which is where the measured speedup over the
//! auto-vectorized scalar bodies comes from.

use super::Matrix;
use std::sync::atomic::{AtomicU8, Ordering};

// ---------------------------------------------------------------------------
// Runtime SIMD dispatch seam
// ---------------------------------------------------------------------------

/// Dispatch cache: 0 = unresolved, 1 = scalar bodies, 2 = AVX2 bodies.
static SIMD_MODE: AtomicU8 = AtomicU8::new(0);
const MODE_SCALAR: u8 = 1;
const MODE_AVX2: u8 = 2;

#[cfg(target_arch = "x86_64")]
fn detect_simd() -> u8 {
    if std::arch::is_x86_64_feature_detected!("avx2") {
        MODE_AVX2
    } else {
        MODE_SCALAR
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_simd() -> u8 {
    MODE_SCALAR
}

fn resolve_simd() -> u8 {
    match std::env::var("DPMM_SIMD").as_deref() {
        Ok("0") | Ok("off") | Ok("false") | Ok("scalar") => MODE_SCALAR,
        // "auto", "on", "avx2", unset, anything else: use what the CPU has.
        _ => detect_simd(),
    }
}

fn simd_mode() -> u8 {
    match SIMD_MODE.load(Ordering::Relaxed) {
        0 => {
            let m = resolve_simd();
            SIMD_MODE.store(m, Ordering::Relaxed);
            m
        }
        m => m,
    }
}

/// Whether the explicit-SIMD kernel bodies are live (AVX2 detected and not
/// disabled via `DPMM_SIMD=off`). Output is bitwise-identical either way;
/// this only selects which body computes it.
pub fn simd_active() -> bool {
    simd_mode() == MODE_AVX2
}

/// Force the SIMD bodies on or off, overriding `DPMM_SIMD` (bench A/B
/// switch and equivalence-test hook). Requesting `true` on hardware
/// without AVX2 stays scalar; the return value is the mode actually in
/// effect after the call.
pub fn set_simd_enabled(on: bool) -> bool {
    let mode = if on { detect_simd() } else { MODE_SCALAR };
    SIMD_MODE.store(mode, Ordering::Relaxed);
    mode == MODE_AVX2
}

/// Human-readable name of the active kernel body (for bench JSON legs).
pub fn simd_label() -> &'static str {
    if simd_active() {
        "avx2"
    } else {
        "scalar"
    }
}

/// AVX2 kernel bodies. Safety: every function is `#[target_feature
/// (enable = "avx2")]` and only ever called behind [`simd_active`] (cached
/// `is_x86_64_feature_detected!("avx2")`), and all pointer arithmetic is
/// bounded by the callers' `debug_assert!`-checked slice lengths.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// `y[t] += c · x[t]` over `y.len()` lanes. Separate mul + add per
    /// lane (no FMA) — bitwise the scalar expression.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available and `x.len() >= y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(c: f64, x: &[f64], y: &mut [f64]) {
        let n = y.len();
        let cv = _mm256_set1_pd(c);
        let mut t = 0;
        while t + 4 <= n {
            let xv = _mm256_loadu_pd(x.as_ptr().add(t));
            let yv = _mm256_loadu_pd(y.as_ptr().add(t));
            _mm256_storeu_pd(y.as_mut_ptr().add(t), _mm256_add_pd(yv, _mm256_mul_pd(cv, xv)));
            t += 4;
        }
        while t < n {
            *y.get_unchecked_mut(t) += c * *x.get_unchecked(t);
            t += 1;
        }
    }

    /// Register-blocked `Y[i] = Σ_j L[i][j] · X[j]` row of the blocked
    /// lower-triangular GEMM: for each 4-lane chunk of columns the
    /// accumulator lives in a register across the whole `j` loop, starting
    /// from the current `y` contents (zeros on first panel touch).
    /// Ascending-`j` accumulation per lane — bitwise the scalar body.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available; `w_row.len()` rows of `x` at
    /// stride `stride` and `y[..m]` must be in bounds.
    #[target_feature(enable = "avx2")]
    pub unsafe fn row_accumulate(w_row: &[f64], x: &[f64], stride: usize, m: usize, y: &mut [f64]) {
        let mut t = 0;
        while t + 4 <= m {
            let mut acc = _mm256_loadu_pd(y.as_ptr().add(t));
            for (j, &wij) in w_row.iter().enumerate() {
                let xv = _mm256_loadu_pd(x.as_ptr().add(j * stride + t));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(_mm256_set1_pd(wij), xv));
            }
            _mm256_storeu_pd(y.as_mut_ptr().add(t), acc);
            t += 4;
        }
        while t < m {
            let mut acc = *y.get_unchecked(t);
            for (j, &wij) in w_row.iter().enumerate() {
                acc += wij * *x.get_unchecked(j * stride + t);
            }
            *y.get_unchecked_mut(t) = acc;
            t += 1;
        }
    }

    /// One row of the fused affine + squared-norm kernel:
    /// `maha[t] += (−b_i + Σ_j w_row[j]·x[j·m+t])²`, with the row value
    /// held in a register across the whole `j` loop. Per-lane order is
    /// exactly the scalar body's (`−bᵢ`, then ascending `j`, then square).
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available; `w_row.len()` rows of `x` at
    /// stride `m` and `maha[..m]` must be in bounds.
    #[target_feature(enable = "avx2")]
    pub unsafe fn row_affine_sqnorm(w_row: &[f64], bi: f64, x: &[f64], m: usize, maha: &mut [f64]) {
        let mut t = 0;
        while t + 4 <= m {
            let mut yv = _mm256_set1_pd(-bi);
            for (j, &wij) in w_row.iter().enumerate() {
                let xv = _mm256_loadu_pd(x.as_ptr().add(j * m + t));
                yv = _mm256_add_pd(yv, _mm256_mul_pd(_mm256_set1_pd(wij), xv));
            }
            let mh = _mm256_loadu_pd(maha.as_ptr().add(t));
            _mm256_storeu_pd(maha.as_mut_ptr().add(t), _mm256_add_pd(mh, _mm256_mul_pd(yv, yv)));
            t += 4;
        }
        while t < m {
            let mut yt = -bi;
            for (j, &wij) in w_row.iter().enumerate() {
                yt += wij * *x.get_unchecked(j * m + t);
            }
            *maha.get_unchecked_mut(t) += yt * yt;
            t += 1;
        }
    }
}

/// Transpose `m` row-major points of dimension `d` into the feature-major
/// tile layout: `out[i * m + t] = rows[t * d + i]`.
pub fn transpose_tile(rows: &[f64], d: usize, m: usize, out: &mut [f64]) {
    debug_assert!(rows.len() >= m * d);
    debug_assert!(out.len() >= d * m);
    for t in 0..m {
        let point = &rows[t * d..(t + 1) * d];
        for (i, &v) in point.iter().enumerate() {
            out[i * m + t] = v;
        }
    }
}

/// Blocked lower-triangular GEMM `Y = L · X` with `L` lower-triangular
/// `d × d` and `X` of shape `d × m` (both row-major). Columns are processed
/// in panels so the active strip of `X` and `Y` stays cache-resident while
/// the triangle of `L` streams through once per panel.
///
/// This is the unfused building block (kept `Matrix → Matrix` for reuse and
/// testability); the assignment hot path uses [`lower_affine_sqnorm`], which
/// fuses the affine offset and squared-norm reduction into the same pass.
pub fn gemm_lower_blocked(l: &Matrix, x: &Matrix) -> Matrix {
    assert_eq!(l.rows(), l.cols(), "L must be square");
    assert_eq!(l.cols(), x.rows(), "shape mismatch");
    const PANEL: usize = 128;
    let d = l.rows();
    let m = x.cols();
    let mut y = Matrix::zeros(d, m);
    let ld = l.data();
    let simd = simd_active();
    let mut col = 0;
    while col < m {
        let w = PANEL.min(m - col);
        for i in 0..d {
            let w_row = &ld[i * d..i * d + i + 1];
            row_accumulate_into(
                simd,
                w_row,
                &x.data()[col..],
                m,
                w,
                &mut y.data_mut()[i * m + col..i * m + col + w],
            );
        }
        col += w;
    }
    y
}

/// Dispatching row accumulator `y[t] += Σ_j w_row[j] · x[j·stride + t]`
/// over `y[..m]` — shared by [`gemm_lower_blocked`] and
/// [`dot_accumulate_tile`]. The scalar and AVX2 bodies are bitwise
/// equivalent (see the module docs).
#[inline]
fn row_accumulate_into(simd: bool, w_row: &[f64], x: &[f64], stride: usize, m: usize, y: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if simd {
        // Safety: `simd` is gated on simd_active() (cached AVX2
        // detection); callers guarantee `w_row.len()` rows of `x` at
        // `stride` and `y[..m]` are in bounds.
        unsafe { avx2::row_accumulate(w_row, x, stride, m, y) };
        return;
    }
    let _ = simd;
    for (j, &wij) in w_row.iter().enumerate() {
        let xrow = &x[j * stride..j * stride + m];
        for (yv, &xv) in y[..m].iter_mut().zip(xrow) {
            *yv += wij * xv;
        }
    }
}

/// Fused whitened-GEMM + squared-norm kernel:
/// `maha[t] = ‖W·x_t − b‖²` for the first `m` columns of the feature-major
/// tile `x` (row stride `m`), with `W` lower-triangular `d × d` (row-major
/// flat slice) and `b` a precomputed affine offset (`b = W·μ`, so no
/// per-point diff vector is ever formed).
///
/// `y` is caller scratch of length ≥ `m` (the current output row).
/// Accumulation order per element: `y = ((−b_i + W_i0·x_0) + W_i1·x_1) + …`,
/// then `maha += y²` for ascending `i` — exactly the scalar-oracle order.
pub fn lower_affine_sqnorm(
    w: &[f64],
    d: usize,
    b: &[f64],
    x: &[f64],
    m: usize,
    y: &mut [f64],
    maha: &mut [f64],
) {
    debug_assert!(w.len() >= d * d);
    debug_assert!(b.len() >= d);
    debug_assert!(x.len() >= d * m);
    debug_assert!(y.len() >= m && maha.len() >= m);
    maha[..m].fill(0.0);
    let simd = simd_active();
    let mut off = 0;
    for i in 0..d {
        let bi = b[i];
        let w_row = &w[off..off + i + 1];
        #[cfg(target_arch = "x86_64")]
        if simd {
            // Safety: gated on simd_active() (cached AVX2 detection); the
            // debug-asserted shapes bound every access.
            unsafe { avx2::row_affine_sqnorm(w_row, bi, x, m, &mut maha[..m]) };
            off += d;
            continue;
        }
        let _ = simd;
        y[..m].fill(-bi);
        for (j, &wij) in w_row.iter().enumerate() {
            let xrow = &x[j * m..j * m + m];
            for (yv, &xv) in y[..m].iter_mut().zip(xrow) {
                *yv += wij * xv;
            }
        }
        for (mh, &yv) in maha[..m].iter_mut().zip(y[..m].iter()) {
            *mh += yv * yv;
        }
        off += d;
    }
}

/// Batched dot product `acc[t] = Σ_j coef[j] · x[j][t]` over the first `m`
/// columns of the feature-major tile `x` (row stride `m`) — the multinomial
/// log-likelihood kernel (`coef = log θ`). Ascending-`j` accumulation,
/// matching the scalar oracle bitwise.
pub fn dot_accumulate_tile(coef: &[f64], x: &[f64], m: usize, acc: &mut [f64]) {
    debug_assert!(x.len() >= coef.len() * m);
    debug_assert!(acc.len() >= m);
    acc[..m].fill(0.0);
    row_accumulate_into(simd_active(), coef, x, m, m, &mut acc[..m]);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lower(d: usize, seed: u64) -> Matrix {
        let mut m = Matrix::zeros(d, d);
        let mut s = seed;
        for i in 0..d {
            for j in 0..=i {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                m[(i, j)] = ((s >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
            }
            m[(i, i)] += 1.5;
        }
        m
    }

    fn dense(r: usize, c: usize, seed: u64) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        let mut s = seed;
        for v in m.data_mut() {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
            *v = ((s >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
        }
        m
    }

    #[test]
    fn transpose_tile_roundtrip() {
        let d = 3;
        let m = 4;
        let rows: Vec<f64> = (0..d * m).map(|v| v as f64).collect();
        let mut out = vec![0.0; d * m];
        transpose_tile(&rows, d, m, &mut out);
        for t in 0..m {
            for i in 0..d {
                assert_eq!(out[i * m + t], rows[t * d + i]);
            }
        }
    }

    #[test]
    fn gemm_lower_blocked_matches_matmul() {
        for (d, m) in [(1, 1), (4, 7), (8, 200), (16, 131)] {
            let l = lower(d, d as u64);
            let x = dense(d, m, m as u64);
            let got = gemm_lower_blocked(&l, &x);
            let want = l.matmul(&x);
            assert!(got.frob_dist(&want) < 1e-12, "d={d} m={m}");
        }
    }

    #[test]
    fn lower_affine_sqnorm_matches_reference() {
        let d = 5;
        let m = 9;
        let l = lower(d, 3);
        let mu: Vec<f64> = (0..d).map(|i| 0.3 * i as f64 - 0.7).collect();
        // b = W·μ
        let b: Vec<f64> = (0..d)
            .map(|i| (0..=i).map(|j| l[(i, j)] * mu[j]).sum())
            .collect();
        let pts = dense(m, d, 17);
        let mut xt = vec![0.0; d * m];
        transpose_tile(pts.data(), d, m, &mut xt);
        let mut y = vec![0.0; m];
        let mut maha = vec![0.0; m];
        lower_affine_sqnorm(l.data(), d, &b, &xt, m, &mut y, &mut maha);
        for t in 0..m {
            // Reference: ‖L(x − μ)‖² via explicit diff.
            let x = pts.row(t);
            let mut want = 0.0;
            for i in 0..d {
                let mut acc = 0.0;
                for j in 0..=i {
                    acc += l[(i, j)] * (x[j] - mu[j]);
                }
                want += acc * acc;
            }
            assert!((maha[t] - want).abs() < 1e-9, "t={t}: {} vs {want}", maha[t]);
        }
    }

    #[test]
    fn dot_accumulate_tile_matches_scalar() {
        let d = 6;
        let m = 5;
        let coef: Vec<f64> = (0..d).map(|j| (j as f64 + 1.0).ln()).collect();
        let pts = dense(m, d, 5);
        let mut xt = vec![0.0; d * m];
        transpose_tile(pts.data(), d, m, &mut xt);
        let mut acc = vec![0.0; m];
        dot_accumulate_tile(&coef, &xt, m, &mut acc);
        for t in 0..m {
            let want: f64 = pts.row(t).iter().zip(&coef).map(|(&x, &c)| x * c).sum();
            assert!((acc[t] - want).abs() < 1e-12);
        }
    }

    /// The AVX2 bodies must be *bitwise* equal to the scalar bodies for
    /// every kernel, including ragged remainders (m not a multiple of the
    /// lane width). On hardware without AVX2 the forced-on mode falls back
    /// to scalar and the comparison is trivially exact.
    ///
    /// The dispatch-override assertions live in the same test because
    /// [`set_simd_enabled`] mutates process-global state: two tests
    /// flipping it concurrently would race (the *kernels* are safe under
    /// such races — both bodies are bitwise equal — but assertions about
    /// the flag itself are not).
    #[test]
    fn simd_bodies_bitwise_match_scalar() {
        let was = simd_active();
        assert!(!set_simd_enabled(false));
        assert!(!simd_active());
        // Forcing on only sticks where AVX2 exists; either way the label
        // and the active flag agree.
        let on = set_simd_enabled(true);
        assert_eq!(on, simd_active());
        assert_eq!(simd_label(), if on { "avx2" } else { "scalar" });
        set_simd_enabled(was);
        for (d, m) in [(1, 1), (2, 3), (5, 9), (8, 128), (16, 131), (32, 7), (33, 130)] {
            let l = lower(d, d as u64 + 1);
            let mu: Vec<f64> = (0..d).map(|i| 0.17 * i as f64 - 0.4).collect();
            let b: Vec<f64> =
                (0..d).map(|i| (0..=i).map(|j| l[(i, j)] * mu[j]).sum()).collect();
            let pts = dense(m, d, 31 + m as u64);
            let mut xt = vec![0.0; d * m];
            transpose_tile(pts.data(), d, m, &mut xt);
            let coef: Vec<f64> = (0..d).map(|j| ((j + 2) as f64).ln()).collect();
            let xcols = dense(d, m, 77);

            let was = simd_active();
            set_simd_enabled(false);
            let mut y = vec![0.0; m];
            let mut maha_s = vec![0.0; m];
            lower_affine_sqnorm(l.data(), d, &b, &xt, m, &mut y, &mut maha_s);
            let mut acc_s = vec![0.0; m];
            dot_accumulate_tile(&coef, &xt, m, &mut acc_s);
            let gemm_s = gemm_lower_blocked(&l, &xcols);

            set_simd_enabled(true);
            let mut maha_v = vec![0.0; m];
            lower_affine_sqnorm(l.data(), d, &b, &xt, m, &mut y, &mut maha_v);
            let mut acc_v = vec![0.0; m];
            dot_accumulate_tile(&coef, &xt, m, &mut acc_v);
            let gemm_v = gemm_lower_blocked(&l, &xcols);
            set_simd_enabled(was);

            for t in 0..m {
                assert_eq!(
                    maha_s[t].to_bits(),
                    maha_v[t].to_bits(),
                    "maha d={d} m={m} t={t}"
                );
                assert_eq!(acc_s[t].to_bits(), acc_v[t].to_bits(), "dot d={d} m={m} t={t}");
            }
            for (s, v) in gemm_s.data().iter().zip(gemm_v.data()) {
                assert_eq!(s.to_bits(), v.to_bits(), "gemm d={d} m={m}");
            }
        }
    }

    #[test]
    fn remainder_tiles_use_only_m_columns() {
        // Buffers larger than m: only the first m entries are touched.
        let d = 2;
        let l = lower(d, 9);
        let b = vec![0.0; d];
        let xt = vec![1.0; d * 3];
        let mut y = vec![f64::NAN; 8];
        let mut maha = vec![f64::NAN; 8];
        lower_affine_sqnorm(l.data(), d, &b, &xt, 3, &mut y, &mut maha);
        assert!(maha[..3].iter().all(|v| v.is_finite()));
        assert!(maha[3..].iter().all(|v| v.is_nan()));
    }
}
