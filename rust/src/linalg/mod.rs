//! Dense linear algebra substrate (the paper's Eigen + `logdet` gist).
//!
//! Small SPD-centric toolkit sized for mixture components: `d ≤ a few
//! hundred`. Row-major `f64` storage, Cholesky factorization, triangular
//! solves, SPD inverse, log-determinant, and the matmul flavors the
//! assignment hot path needs.

mod matrix;
mod tile;

pub use matrix::Matrix;
pub use tile::{
    dot_accumulate_tile, gemm_lower_blocked, lower_affine_sqnorm, set_simd_enabled, simd_active,
    simd_label, transpose_tile,
};

/// log(det(Σ)) of an SPD matrix via Cholesky: 2·Σ log Lᵢᵢ.
pub fn spd_logdet(m: &Matrix) -> Option<f64> {
    m.cholesky().map(|l| 2.0 * (0..m.rows()).map(|i| l[(i, i)].ln()).sum::<f64>())
}

/// Solve L x = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut acc = b[i];
        for j in 0..i {
            acc -= l[(i, j)] * x[j];
        }
        x[i] = acc / l[(i, i)];
    }
    x
}

/// Solve Lᵀ x = b for lower-triangular L (back substitution).
pub fn solve_lower_transpose(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut acc = b[i];
        for j in i + 1..n {
            acc -= l[(j, i)] * x[j];
        }
        x[i] = acc / l[(i, i)];
    }
    x
}

/// Solve (L Lᵀ) x = b given the Cholesky factor L.
pub fn chol_solve(l: &Matrix, b: &[f64]) -> Vec<f64> {
    solve_lower_transpose(l, &solve_lower(l, b))
}

/// Mahalanobis squared distance (x−μ)ᵀ Σ⁻¹ (x−μ) given L = chol(Σ).
pub fn mahalanobis_sq(l: &Matrix, x: &[f64], mu: &[f64]) -> f64 {
    let d = x.len();
    let mut diff = vec![0.0; d];
    for i in 0..d {
        diff[i] = x[i] - mu[i];
    }
    let y = solve_lower(l, &diff);
    y.iter().map(|v| v * v).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd3() -> Matrix {
        // A = Bᵀ B + I for a fixed B → SPD.
        let b = Matrix::from_rows(&[
            &[1.0, 2.0, 0.5],
            &[0.0, 1.5, -1.0],
            &[2.0, 0.0, 1.0],
        ]);
        let mut a = b.transpose().matmul(&b);
        for i in 0..3 {
            a[(i, i)] += 1.0;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd3();
        let l = a.cholesky().unwrap();
        let back = l.mul_transpose();
        for i in 0..3 {
            for j in 0..3 {
                assert!((back[(i, j)] - a[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn logdet_matches_2x2_closed_form() {
        let mut m = Matrix::zeros(2, 2);
        m[(0, 0)] = 3.0;
        m[(0, 1)] = 1.0;
        m[(1, 0)] = 1.0;
        m[(1, 1)] = 2.0;
        let det: f64 = 3.0 * 2.0 - 1.0;
        assert!((spd_logdet(&m).unwrap() - det.ln()).abs() < 1e-12);
    }

    #[test]
    fn triangular_solves_invert() {
        let a = spd3();
        let l = a.cholesky().unwrap();
        let b = [1.0, -2.0, 0.5];
        let x = chol_solve(&l, &b);
        // Check A x = b
        for i in 0..3 {
            let mut acc = 0.0;
            for j in 0..3 {
                acc += a[(i, j)] * x[j];
            }
            assert!((acc - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn mahalanobis_identity_cov_is_euclidean() {
        let l = Matrix::identity(3).cholesky().unwrap();
        let x = [1.0, 2.0, 3.0];
        let mu = [0.0, 0.0, 1.0];
        assert!((mahalanobis_sq(&l, &x, &mu) - (1.0 + 4.0 + 4.0)).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let mut m = Matrix::identity(2);
        m[(0, 0)] = -1.0;
        assert!(m.cholesky().is_none());
    }
}
