#!/usr/bin/env python3
"""Prometheus text-exposition (0.0.4) lint for CI scrape validation.

Reads an exposition document from a file argument (or stdin with `-`) and
checks the invariants the dpmm renderer guarantees
(rust/src/telemetry/text.rs):

* every sample line parses as `name[{labels}] value [timestamp]` with a
  legal metric name and a float-parseable value;
* every sample's family is declared by a preceding `# TYPE` line, and
  `# TYPE` names/kinds are unique and legal;
* histogram families expose `_bucket` series ending in `le="+Inf"`, with
  cumulative bucket counts monotone non-decreasing and the +Inf bucket
  equal to `_count`;
* counters never carry a negative value.

Optional `--min-families N` enforces the catalog floor (the acceptance
criterion: leader/worker/serve endpoints expose >= 10 dpmm_* families).

Usage: check_metrics_format.py [--min-families N] FILE|-
"""

import argparse
import re
import sys

NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
KINDS = {"counter", "gauge", "histogram", "summary", "untyped"}


def fail(lineno, line, why):
    print(f"metrics lint: line {lineno}: {why}: {line!r}", file=sys.stderr)
    return 1


def split_sample(line):
    """Split a sample line into (name, labels-dict-or-None, value-str)."""
    brace = line.find("{")
    space = line.find(" ")
    if brace != -1 and (space == -1 or brace < space):
        # Scan for the '}' closing the label set (label values may contain
        # spaces/braces inside their quotes, and escaped quotes).
        in_quotes = False
        escaped = False
        end = None
        for i in range(brace, len(line)):
            c = line[i]
            if escaped:
                escaped = False
            elif c == "\\":
                escaped = in_quotes
            elif c == '"':
                in_quotes = not in_quotes
            elif c == "}" and not in_quotes:
                end = i
                break
        if end is None:
            raise ValueError("unterminated label set")
        name = line[:brace]
        labels = parse_labels(line[brace + 1 : end])
        rest = line[end + 1 :].strip()
    else:
        name, _, rest = line.partition(" ")
        labels = {}
    if not rest:
        raise ValueError("no value")
    return name, labels, rest.split()[0]


def parse_labels(body):
    labels = {}
    rest = body
    while True:
        rest = rest.lstrip(", ")
        if not rest:
            return labels
        eq = rest.find("=")
        if eq == -1:
            raise ValueError("label missing '='")
        key = rest[:eq].strip()
        if not NAME.match(key):
            raise ValueError(f"bad label name {key!r}")
        rest = rest[eq + 1 :]
        if not rest.startswith('"'):
            raise ValueError("label value not quoted")
        rest = rest[1:]
        out = []
        escaped = False
        end = None
        for i, c in enumerate(rest):
            if escaped:
                out.append(c)
                escaped = False
            elif c == "\\":
                escaped = True
            elif c == '"':
                end = i
                break
            else:
                out.append(c)
        if end is None:
            raise ValueError("unterminated label value")
        labels[key] = "".join(out)
        rest = rest[end + 1 :]


def parse_value(v):
    if v == "+Inf":
        return float("inf")
    if v == "-Inf":
        return float("-inf")
    return float(v)  # 'NaN' handled by float()


def lint(text, min_families=0):
    errors = 0
    types = {}  # family name -> kind
    # histogram family -> {labelset-sans-le (frozenset): [(le, count)]}
    buckets = {}
    hist_counts = {}  # (family, labelset) -> _count value
    hist_sums = set()  # (family, labelset) with a _sum sample
    samples = 0

    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 2 and parts[1] == "TYPE":
                if len(parts) < 4:
                    errors += fail(lineno, raw, "malformed # TYPE")
                    continue
                name, kind = parts[2], parts[3].strip()
                if not NAME.match(name):
                    errors += fail(lineno, raw, f"bad family name {name!r}")
                if kind not in KINDS:
                    errors += fail(lineno, raw, f"unknown kind {kind!r}")
                if name in types:
                    errors += fail(lineno, raw, f"duplicate # TYPE for {name}")
                types[name] = kind
            continue
        try:
            name, labels, value_str = split_sample(line)
            value = parse_value(value_str)
        except ValueError as e:
            errors += fail(lineno, raw, str(e))
            continue
        if not NAME.match(name):
            errors += fail(lineno, raw, f"bad metric name {name!r}")
            continue
        samples += 1
        family = name
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[: -len(suffix)] if name.endswith(suffix) else None
            if base and types.get(base) == "histogram":
                family = base
                break
        if family not in types:
            errors += fail(lineno, raw, f"sample before/without # TYPE for {family}")
            continue
        kind = types[family]
        if kind == "counter" and value < 0:
            errors += fail(lineno, raw, "negative counter value")
        if kind == "histogram":
            key = frozenset((k, v) for k, v in labels.items() if k != "le")
            if name.endswith("_bucket"):
                if "le" not in labels:
                    errors += fail(lineno, raw, "_bucket sample without le label")
                    continue
                buckets.setdefault(family, {}).setdefault(key, []).append(
                    (labels["le"], value)
                )
            elif name.endswith("_count"):
                hist_counts[(family, key)] = value
            elif name.endswith("_sum"):
                hist_sums.add((family, key))

    for family, series in buckets.items():
        for key, entries in series.items():
            les = [le for le, _ in entries]
            counts = [c for _, c in entries]
            if les[-1] != "+Inf":
                errors += 1
                print(
                    f"metrics lint: histogram {family}{dict(key)}: bucket series "
                    f"must end at le=\"+Inf\" (got {les!r})",
                    file=sys.stderr,
                )
                continue
            if any(earlier > later for earlier, later in zip(counts, counts[1:])):
                errors += 1
                print(
                    f"metrics lint: histogram {family}{dict(key)}: cumulative "
                    f"buckets not monotone: {counts!r}",
                    file=sys.stderr,
                )
            total = hist_counts.get((family, key))
            if total is None:
                # A bucket series without its _count silently passed the
                # +Inf == _count check before; require the sample outright.
                errors += 1
                print(
                    f"metrics lint: histogram {family}{dict(key)}: missing "
                    f"_count sample",
                    file=sys.stderr,
                )
            elif counts[-1] != total:
                errors += 1
                print(
                    f"metrics lint: histogram {family}{dict(key)}: +Inf bucket "
                    f"{counts[-1]} != _count {total}",
                    file=sys.stderr,
                )
            if (family, key) not in hist_sums:
                errors += 1
                print(
                    f"metrics lint: histogram {family}{dict(key)}: missing "
                    f"_sum sample",
                    file=sys.stderr,
                )

    dpmm_families = sum(1 for n in types if n.startswith("dpmm_"))
    if min_families and dpmm_families < min_families:
        errors += 1
        print(
            f"metrics lint: only {dpmm_families} dpmm_* families "
            f"(need >= {min_families})",
            file=sys.stderr,
        )
    return errors, samples, len(types), dpmm_families


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("file", help="exposition file, or - for stdin")
    ap.add_argument(
        "--min-families",
        type=int,
        default=0,
        help="require at least N dpmm_* metric families",
    )
    args = ap.parse_args()
    text = (
        sys.stdin.read()
        if args.file == "-"
        else open(args.file, encoding="utf-8").read()
    )
    errors, samples, families, dpmm_families = lint(text, args.min_families)
    if errors:
        print(f"metrics lint: {errors} error(s)", file=sys.stderr)
        return 1
    print(
        f"metrics lint: OK ({samples} samples, {families} families, "
        f"{dpmm_families} dpmm_*)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
