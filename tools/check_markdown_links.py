#!/usr/bin/env python3
"""Offline markdown link check over README.md and docs/.

Validates every repo-relative link target exists, and that `#anchor`
fragments resolve to a real heading in the target markdown file.
External links (http/https/mailto) are skipped — CI runs this offline,
and dead-external detection belongs to a different (flaky) class of
check. Exit code 1 + a per-link report on any failure.

Run from anywhere: `python tools/check_markdown_links.py`.
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# Inline links only — [text](target). Reference-style links are unused in
# this repo; add a second pass here if that changes.
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"^(```|~~~)")


def slugify(heading: str) -> str:
    """GitHub-style heading slug (good enough for our ASCII headings)."""
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def visible_lines(path: Path):
    """Markdown lines outside fenced code blocks."""
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if not in_fence:
            yield line


def headings(path: Path) -> set:
    return {
        slugify(line.lstrip("#"))
        for line in visible_lines(path)
        if line.startswith("#")
    }


def check_file(md: Path) -> list:
    errors = []
    for line in visible_lines(md):
        for target in LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = md if not path_part else (md.parent / path_part).resolve()
            rel = md.relative_to(ROOT)
            if not dest.exists():
                errors.append(f"{rel}: broken link target: {target}")
                continue
            if anchor and dest.suffix == ".md":
                if anchor not in headings(dest):
                    errors.append(
                        f"{rel}: anchor #{anchor} not found in {path_part or rel}"
                    )
    return errors


def main() -> int:
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    missing = [f for f in files if not f.exists()]
    if missing:
        print(f"link check: expected files missing: {missing}", file=sys.stderr)
        return 1
    errors = []
    for f in files:
        errors.extend(check_file(f))
    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"\nlink check: {len(errors)} broken link(s)", file=sys.stderr)
        return 1
    print(f"link check: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
